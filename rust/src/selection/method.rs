//! The candidate subsampling methods (paper §3.1) and their α transforms.
//!
//! `Method::ALL` order is FROZEN and must match the L1 score kernel's
//! `METHOD_ORDER` (checked against `artifacts/manifest.json` at runtime and
//! in integration tests).

use crate::util::stats;

/// The seven candidate methods of §3.1.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    Uniform,
    BigLoss,
    SmallLoss,
    GradNorm,
    AdaBoost,
    Coreset1,
    Coreset2,
}

impl Method {
    pub const ALL: [Method; 7] = [
        Method::Uniform,
        Method::BigLoss,
        Method::SmallLoss,
        Method::GradNorm,
        Method::AdaBoost,
        Method::Coreset1,
        Method::Coreset2,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Method::Uniform => "uniform",
            Method::BigLoss => "big_loss",
            Method::SmallLoss => "small_loss",
            Method::GradNorm => "grad_norm",
            Method::AdaBoost => "adaboost",
            Method::Coreset1 => "coreset1",
            Method::Coreset2 => "coreset2",
        }
    }

    pub fn from_name(s: &str) -> anyhow::Result<Method> {
        Method::ALL
            .iter()
            .copied()
            .find(|m| m.name() == s)
            .ok_or_else(|| anyhow::anyhow!("unknown method '{s}'"))
    }

    /// Row index in the kernel's alpha matrix.
    pub fn index(self) -> usize {
        Method::ALL.iter().position(|&m| m == self).unwrap()
    }
}

/// AdaBoost half-log-odds statistic over max-normalized losses (eq. 1).
pub fn adaboost_stat(loss: &[f32]) -> Vec<f32> {
    let max = loss.iter().cloned().fold(f32::MIN, f32::max).max(0.0) + 1e-9;
    loss.iter()
        .map(|&l| {
            let lh = (l / max).clamp(0.0, 1.0 - 1e-3);
            0.5 * ((1.0 + lh) / (1.0 - lh)).ln()
        })
        .collect()
}

/// Coreset distance-to-batch-mean statistic.
pub fn dev_stat(loss: &[f32]) -> Vec<f32> {
    let m = stats::mean(loss);
    loss.iter().map(|&l| (l - m).abs()).collect()
}

/// Scoring cost class of a selection method (what the trainer must pay
/// before the method can rank rows).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScoringCost {
    /// no selection forward pass at all (the no-sampling benchmark)
    None,
    /// one forward pass over the whole arrival batch
    BatchForward,
    /// forward over a candidate subset only (k·⌈γB⌉ rows)
    CandidateForward,
}

impl ScoringCost {
    pub fn name(&self) -> &'static str {
        match self {
            ScoringCost::None => "none",
            ScoringCost::BatchForward => "batch-forward",
            ScoringCost::CandidateForward => "candidate-forward",
        }
    }
}

/// One entry of the method registry: a stable string id plus the metadata
/// the CLI / bandit / docs need. The seven legacy methods keep their frozen
/// kernel alpha-matrix row (`kernel_index`); registry-only methods carry
/// `None` and are computed host-side.
#[derive(Clone, Copy, Debug)]
pub struct MethodSpec {
    pub id: &'static str,
    pub cost: ScoringCost,
    /// row in the L1 kernel's alpha matrix, when the method has one
    pub kernel_index: Option<usize>,
}

/// The method registry: the 7 kernel methods at their frozen indices 0–6,
/// followed by the forward-cheap registry-only methods. Adding a method
/// here (plus an `Arm` variant) is the whole extension surface — the
/// kernel/manifest indices of existing methods never move.
pub const REGISTRY: [MethodSpec; 9] = [
    MethodSpec { id: "uniform", cost: ScoringCost::BatchForward, kernel_index: Some(0) },
    MethodSpec { id: "big_loss", cost: ScoringCost::BatchForward, kernel_index: Some(1) },
    MethodSpec { id: "small_loss", cost: ScoringCost::BatchForward, kernel_index: Some(2) },
    MethodSpec { id: "grad_norm", cost: ScoringCost::BatchForward, kernel_index: Some(3) },
    MethodSpec { id: "adaboost", cost: ScoringCost::BatchForward, kernel_index: Some(4) },
    MethodSpec { id: "coreset1", cost: ScoringCost::BatchForward, kernel_index: Some(5) },
    MethodSpec { id: "coreset2", cost: ScoringCost::BatchForward, kernel_index: Some(6) },
    MethodSpec { id: "obftf", cost: ScoringCost::CandidateForward, kernel_index: None },
    MethodSpec {
        id: "selective-backprop",
        cost: ScoringCost::BatchForward,
        kernel_index: None,
    },
];

/// Every id a selector spec / adaselection pool may name.
pub fn valid_method_ids() -> Vec<&'static str> {
    REGISTRY.iter().map(|s| s.id).collect()
}

/// Look up a registry entry by its stable id.
pub fn lookup(id: &str) -> anyhow::Result<&'static MethodSpec> {
    REGISTRY.iter().find(|s| s.id == id).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown method '{id}' (valid: {})",
            valid_method_ids().join(", ")
        )
    })
}

/// A bandit arm of the AdaSelection pool: either one of the seven kernel
/// methods (α computed by the L1 scorer) or a registry-only forward-cheap
/// method whose α row is computed host-side.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Arm {
    Kernel(Method),
    /// One-Backward-From-Ten-Forward (Dong et al., 2021) as an in-batch
    /// arm: uniform mass over the top ⌈B/k⌉ rows by loss.
    Obftf,
    /// Selective-Backprop (Jiang et al., 2019) as an in-batch arm: mass ∝
    /// the in-batch loss-CDF raised to a power (rank-flattened big-loss).
    SelectiveBackprop,
}

impl From<Method> for Arm {
    fn from(m: Method) -> Arm {
        Arm::Kernel(m)
    }
}

/// CDF power of the selective-backprop α row (Jiang et al. use the squared
/// percentile as the keep probability).
const SB_CDF_POWER: f32 = 2.0;

impl Arm {
    pub fn id(&self) -> &'static str {
        match self {
            Arm::Kernel(m) => m.name(),
            Arm::Obftf => "obftf",
            Arm::SelectiveBackprop => "selective-backprop",
        }
    }

    pub fn from_id(s: &str) -> anyhow::Result<Arm> {
        match s {
            "obftf" => Ok(Arm::Obftf),
            "selective-backprop" => Ok(Arm::SelectiveBackprop),
            other => {
                lookup(other)?; // canonical unknown-id error with the valid list
                Ok(Arm::Kernel(Method::from_name(other)?))
            }
        }
    }

    /// Kernel alpha-matrix row, when this arm is one of the frozen seven.
    pub fn kernel_index(&self) -> Option<usize> {
        match self {
            Arm::Kernel(m) => Some(m.index()),
            _ => None,
        }
    }

    /// α_i for this arm. `obftf_k` is the candidate multiplier the obftf
    /// arm slices the batch with (`--obftf-k`).
    pub fn alpha(&self, loss: &[f32], gnorm: &[f32], obftf_k: usize) -> Vec<f32> {
        match self {
            Arm::Kernel(m) => alpha(*m, loss, gnorm),
            Arm::Obftf => obftf_alpha(loss, obftf_k),
            Arm::SelectiveBackprop => sb_alpha(loss),
        }
    }
}

/// α of the obftf arm: uniform over the top ⌈B/k⌉ rows by loss ("one
/// backward from k forward" — every candidate-slice row equally likely).
fn obftf_alpha(loss: &[f32], k: usize) -> Vec<f32> {
    let b = loss.len();
    let k = k.max(1);
    let slice = ((b + k - 1) / k).clamp(1, b);
    let top = crate::util::topk::top_k_indices(loss, slice);
    let mut a = vec![0.0f32; b];
    let p = 1.0 / top.len().max(1) as f32;
    for i in top {
        a[i] = p;
    }
    a
}

/// α of the selective-backprop arm: in-batch loss-CDF percentile raised to
/// `SB_CDF_POWER`, normalized to a simplex. Monotone in loss like big-loss
/// but rank-flattened, so outlier losses do not dominate the fused score.
fn sb_alpha(loss: &[f32]) -> Vec<f32> {
    let b = loss.len();
    if b == 1 {
        return vec![1.0];
    }
    let order = crate::util::topk::argsort_desc(loss);
    let mut a = vec![0.0f32; b];
    for (rank_desc, &i) in order.iter().enumerate() {
        // percentile ∈ (0, 1]: highest loss → 1, lowest → 1/B
        let pct = (b - rank_desc) as f32 / b as f32;
        a[i] = pct.powf(SB_CDF_POWER);
    }
    let sum: f32 = a.iter().sum();
    for v in a.iter_mut() {
        *v /= sum.max(1e-12);
    }
    a
}

/// α_i^m: softmax over the standardized ordering statistic — the exact
/// pure-rust mirror of the L1 score kernel (see kernels/score.py).
pub fn alpha(method: Method, loss: &[f32], gnorm: &[f32]) -> Vec<f32> {
    let b = loss.len();
    let mut stat: Vec<f32> = match method {
        Method::Uniform => return vec![1.0 / b as f32; b],
        Method::BigLoss => loss.to_vec(),
        Method::SmallLoss => loss.iter().map(|&l| -l).collect(),
        Method::GradNorm => gnorm.to_vec(),
        Method::AdaBoost => adaboost_stat(loss),
        Method::Coreset1 => dev_stat(loss),
        Method::Coreset2 => dev_stat(loss).iter().map(|&d| -d).collect(),
    };
    stats::standardize(&mut stat, 1e-6);
    stats::softmax(&mut stat);
    stat
}

/// All seven alphas, `Method::ALL` order (rows).
pub fn all_alphas(loss: &[f32], gnorm: &[f32]) -> Vec<Vec<f32>> {
    Method::ALL
        .iter()
        .map(|&m| alpha(m, loss, gnorm))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> (Vec<f32>, Vec<f32>) {
        (
            vec![0.1, 2.0, 0.5, 1.0, 4.0, 0.2],
            vec![1.0, 0.5, 2.0, 0.1, 0.3, 1.5],
        )
    }

    #[test]
    fn names_round_trip() {
        for m in Method::ALL {
            assert_eq!(Method::from_name(m.name()).unwrap(), m);
        }
        assert!(Method::from_name("nope").is_err());
    }

    #[test]
    fn alphas_are_simplex() {
        let (l, g) = toy();
        for m in Method::ALL {
            let a = alpha(m, &l, &g);
            assert_eq!(a.len(), l.len());
            let sum: f32 = a.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5, "{m:?} sum={sum}");
            assert!(a.iter().all(|&x| x >= 0.0), "{m:?}");
        }
    }

    #[test]
    fn big_loss_ranks_by_loss() {
        let (l, g) = toy();
        let a = alpha(Method::BigLoss, &l, &g);
        let max_i = l
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(
            a.iter()
                .enumerate()
                .max_by(|x, y| x.1.partial_cmp(y.1).unwrap())
                .unwrap()
                .0,
            max_i
        );
    }

    #[test]
    fn small_is_reverse_of_big() {
        let (l, g) = toy();
        let big = alpha(Method::BigLoss, &l, &g);
        let small = alpha(Method::SmallLoss, &l, &g);
        let ord_big: Vec<usize> = crate::util::topk::argsort_desc(&big);
        let mut ord_small: Vec<usize> = crate::util::topk::argsort_desc(&small);
        ord_small.reverse();
        assert_eq!(ord_big, ord_small);
    }

    #[test]
    fn gradnorm_uses_gnorm_not_loss() {
        let (l, g) = toy();
        let a = alpha(Method::GradNorm, &l, &g);
        // sample 2 has the highest gnorm
        let max_i = a
            .iter()
            .enumerate()
            .max_by(|x, y| x.1.partial_cmp(y.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(max_i, 2);
    }

    #[test]
    fn adaboost_monotone_in_loss() {
        let (l, _) = toy();
        let s = adaboost_stat(&l);
        let mut idx: Vec<usize> = (0..l.len()).collect();
        idx.sort_by(|&a, &b| l[a].partial_cmp(&l[b]).unwrap());
        for w in idx.windows(2) {
            assert!(s[w[0]] <= s[w[1]] + 1e-7);
        }
    }

    #[test]
    fn coreset2_favors_near_mean() {
        let (l, g) = toy();
        let a = alpha(Method::Coreset2, &l, &g);
        let m = stats::mean(&l);
        let closest = l
            .iter()
            .enumerate()
            .min_by(|x, y| {
                (x.1 - m).abs().partial_cmp(&(y.1 - m).abs()).unwrap()
            })
            .unwrap()
            .0;
        let max_a = a
            .iter()
            .enumerate()
            .max_by(|x, y| x.1.partial_cmp(y.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(max_a, closest);
    }

    #[test]
    fn registry_keeps_legacy_kernel_indices_frozen() {
        // the 7 kernel methods stay at their frozen rows; registry-only
        // methods carry no kernel row
        for m in Method::ALL {
            let spec = lookup(m.name()).unwrap();
            assert_eq!(spec.kernel_index, Some(m.index()), "{}", m.name());
        }
        assert_eq!(lookup("obftf").unwrap().kernel_index, None);
        assert_eq!(lookup("selective-backprop").unwrap().kernel_index, None);
        assert_eq!(lookup("obftf").unwrap().cost, ScoringCost::CandidateForward);
        let err = lookup("bogus").unwrap_err().to_string();
        assert!(err.contains("obftf") && err.contains("big_loss"), "{err}");
        assert_eq!(valid_method_ids().len(), REGISTRY.len());
    }

    #[test]
    fn arm_ids_round_trip() {
        for spec in REGISTRY {
            let arm = Arm::from_id(spec.id).unwrap();
            assert_eq!(arm.id(), spec.id);
            assert_eq!(arm.kernel_index(), spec.kernel_index);
        }
        assert!(Arm::from_id("nope").is_err());
    }

    #[test]
    fn obftf_alpha_is_uniform_over_top_slice() {
        let loss = [0.1f32, 5.0, 0.2, 4.0, 0.3, 3.0, 0.4, 2.0];
        let a = Arm::Obftf.alpha(&loss, &loss, 4); // slice = ⌈8/4⌉ = 2
        let nonzero: Vec<usize> =
            (0..a.len()).filter(|&i| a[i] > 0.0).collect();
        assert_eq!(nonzero, vec![1, 3], "{a:?}"); // two biggest losses
        assert!((a[1] - 0.5).abs() < 1e-6 && (a[3] - 0.5).abs() < 1e-6);
        let sum: f32 = a.iter().sum();
        assert!((sum - 1.0).abs() < 1e-5);
    }

    #[test]
    fn sb_alpha_is_monotone_rank_flattened_simplex() {
        let loss = [0.5f32, 3.0, 1.0, 100.0, 0.1];
        let a = Arm::SelectiveBackprop.alpha(&loss, &loss, 10);
        let sum: f32 = a.iter().sum();
        assert!((sum - 1.0).abs() < 1e-5);
        // monotone in loss
        let mut idx: Vec<usize> = (0..loss.len()).collect();
        idx.sort_by(|&x, &y| loss[x].partial_cmp(&loss[y]).unwrap());
        for w in idx.windows(2) {
            assert!(a[w[0]] <= a[w[1]] + 1e-7);
        }
        // rank-based: the 100.0 outlier gets the top-rank mass, not
        // outlier-proportional mass (contrast with raw-loss weighting)
        assert!(a[3] < 0.5, "{a:?}");
    }

    #[test]
    fn frozen_order_matches_kernel() {
        let names: Vec<&str> = Method::ALL.iter().map(|m| m.name()).collect();
        assert_eq!(
            names,
            vec![
                "uniform",
                "big_loss",
                "small_loss",
                "grad_norm",
                "adaboost",
                "coreset1",
                "coreset2"
            ]
        );
    }
}

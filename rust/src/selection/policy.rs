//! The `Selector` abstraction the trainer drives: one implementation per
//! baseline (§3.1 semantics) plus the forward-cheap methods (OBFTF,
//! Selective-Backprop), AdaSelection, and the no-sampling benchmark.
//!
//! Selection is two-phase. Phase 1 (`Selector::plan`) declares which rows
//! of the arriving batch need forward-only scoring — `ScoringNeeds` names
//! the cost class, the plan pins the concrete candidate rows. Phase 2
//! (`Selector::select`) runs over the scored candidates and returns the
//! rows to backprop on. Most policies score the whole batch; the benchmark
//! scores nothing; OBFTF scores a k·(target) candidate superset only.

use crate::selection::adaselection::{AdaConfig, AdaSelection};
use crate::selection::method::{adaboost_stat, dev_stat, valid_method_ids, Arm, Method};
use crate::util::rng::Pcg64;
use crate::util::topk::{argsort_desc, bottom_k_indices, top_k_indices};

/// What the selection forward pass must produce for a policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScoringNeeds {
    /// no selection forward pass at all (the no-sampling benchmark)
    None,
    /// per-sample loss/gnorm over every real row of the arriving batch
    BatchForward,
    /// per-sample loss/gnorm over a candidate subset of ≈ k·(target rows)
    CandidateForward { k: usize },
}

/// Phase-1 output: the rows needing forward-only scoring this iteration.
#[derive(Clone, Debug, Default)]
pub struct SelectionPlan {
    /// candidate rows (batch positions, strictly increasing); `None` means
    /// every real row — the degenerate full-batch plan
    pub candidate_rows: Option<Vec<usize>>,
}

/// Minimal view of the historical per-sample loss distribution a policy
/// may consult at select time (implemented by `stream::store::InstanceStore`).
pub trait LossHistory {
    /// The q-quantile (q ∈ [0, 1]) of live historical losses, deterministic
    /// given identical history; `None` when the history is empty.
    fn loss_quantile(&self, q: f32) -> Option<f32>;
}

/// Inputs available to a policy at iteration t. `loss`/`gnorm` cover the
/// scored candidate rows (the whole batch unless phase 1 planned a subset),
/// so `select` returns candidate-local positions.
pub struct SelectionContext<'a> {
    /// per-sample losses over the scored rows
    pub loss: &'a [f32],
    /// per-sample gradient-norm proxies
    pub gnorm: &'a [f32],
    /// subset size k = ceil(γ·B)
    pub k: usize,
    /// historical loss distribution (selective-backprop threshold source)
    pub history: Option<&'a dyn LossHistory>,
}

/// A subsampling policy.
pub trait Selector: Send {
    /// Stable identifier used in reports (e.g. "big_loss", "adaselection").
    fn name(&self) -> String;

    /// The cost class of this policy's selection forward pass.
    fn scoring(&self) -> ScoringNeeds {
        ScoringNeeds::BatchForward
    }

    /// Phase 1: declare the candidate rows to forward-score for a batch of
    /// `arrivals` real rows targeting `k` kept rows. Advances sampler
    /// state for stochastic planners, so call exactly once per iteration.
    fn plan(&mut self, _arrivals: usize, _k: usize) -> SelectionPlan {
        SelectionPlan::default()
    }

    /// Phase 2: rows (positions within the scored candidate set) to keep,
    /// deterministic given state.
    fn select(&mut self, ctx: &SelectionContext) -> Vec<usize>;

    /// AdaSelection's method weights, if any (Fig-8 traces).
    fn weights(&self) -> Option<Vec<f32>> {
        None
    }
}

/// No subsampling: keep every row (the paper's "Benchmark" column).
pub struct BenchmarkAll;

impl Selector for BenchmarkAll {
    fn name(&self) -> String {
        "benchmark".into()
    }

    fn scoring(&self) -> ScoringNeeds {
        ScoringNeeds::None
    }

    fn select(&mut self, ctx: &SelectionContext) -> Vec<usize> {
        (0..ctx.loss.len()).collect()
    }
}

/// One fixed baseline method, with the paper's §3.1 selection semantics:
/// deterministic top/bottom-k for the ranking methods, 50/50 extremes for
/// Coreset1, closest-to-mean for Coreset2, and sampling for Uniform /
/// AdaBoost (importance sampling ∝ the eq.-1 weights).
pub struct SingleMethod {
    pub method: Method,
    rng: Pcg64,
}

impl SingleMethod {
    pub fn new(method: Method, seed: u64) -> Self {
        SingleMethod {
            method,
            rng: Pcg64::new(seed ^ 0xd15e_a5e5),
        }
    }

    /// Raw sampler state (checkpoint support for the stochastic methods).
    pub fn rng_words(&self) -> [u64; 4] {
        self.rng.state_words()
    }

    /// Restore sampler state captured by [`SingleMethod::rng_words`].
    pub fn set_rng_words(&mut self, w: [u64; 4]) {
        self.rng = Pcg64::from_state_words(w);
    }

    /// Sample k distinct rows with probability ∝ weights (systematic
    /// weighted reservoir via repeated draws; k ≪ B in practice).
    fn weighted_k(&mut self, weights: &[f32], k: usize) -> Vec<usize> {
        let mut w: Vec<f64> = weights.iter().map(|&x| (x.max(0.0)) as f64 + 1e-12).collect();
        let k = k.min(w.len());
        let mut out = Vec::with_capacity(k);
        for _ in 0..k {
            let i = self.rng.weighted_index(&w);
            out.push(i);
            w[i] = 0.0;
        }
        out.sort_unstable();
        out
    }
}

impl Selector for SingleMethod {
    fn name(&self) -> String {
        self.method.name().into()
    }

    fn select(&mut self, ctx: &SelectionContext) -> Vec<usize> {
        let k = ctx.k.min(ctx.loss.len());
        match self.method {
            Method::Uniform => {
                let mut idx = self.rng.permutation(ctx.loss.len());
                idx.truncate(k);
                idx.sort_unstable();
                idx
            }
            Method::BigLoss => top_k_indices(ctx.loss, k),
            Method::SmallLoss => bottom_k_indices(ctx.loss, k),
            Method::GradNorm => top_k_indices(ctx.gnorm, k),
            Method::AdaBoost => {
                let w = adaboost_stat(ctx.loss);
                self.weighted_k(&w, k)
            }
            Method::Coreset1 => {
                // 50% biggest + 50% smallest (odd k: extra from the top)
                let top = top_k_indices(ctx.loss, k - k / 2);
                let mut bot = bottom_k_indices(ctx.loss, k / 2);
                let mut out = top;
                // avoid duplicates when k approaches B
                bot.retain(|i| !out.contains(i));
                out.append(&mut bot);
                while out.len() < k {
                    if let Some(i) = (0..ctx.loss.len()).find(|i| !out.contains(i)) {
                        out.push(i);
                    } else {
                        break;
                    }
                }
                out
            }
            Method::Coreset2 => bottom_k_indices(&dev_stat(ctx.loss), k),
        }
    }
}

/// One Backward From Ten Forward (Dong et al., 2021): forward-score only a
/// random candidate superset of `mult`·k rows, then backprop the top-k of
/// those by loss. When `mult`·k covers the batch the plan degenerates to a
/// full-batch forward — still one backward on k rows.
pub struct ObftfPolicy {
    mult: usize,
    rng: Pcg64,
}

impl ObftfPolicy {
    pub fn new(mult: usize, seed: u64) -> Self {
        ObftfPolicy {
            mult: mult.max(1),
            rng: Pcg64::new(seed ^ 0x0bf7_f0bf),
        }
    }

    /// The candidate multiplier k of "k forward, one backward".
    pub fn mult(&self) -> usize {
        self.mult
    }

    /// Raw sampler state (checkpoint support).
    pub fn rng_words(&self) -> [u64; 4] {
        self.rng.state_words()
    }

    /// Restore sampler state captured by [`ObftfPolicy::rng_words`].
    pub fn set_rng_words(&mut self, w: [u64; 4]) {
        self.rng = Pcg64::from_state_words(w);
    }
}

impl Selector for ObftfPolicy {
    fn name(&self) -> String {
        "obftf".into()
    }

    fn scoring(&self) -> ScoringNeeds {
        ScoringNeeds::CandidateForward { k: self.mult }
    }

    fn plan(&mut self, arrivals: usize, k: usize) -> SelectionPlan {
        let want = self.mult.saturating_mul(k.max(1));
        if want >= arrivals {
            return SelectionPlan::default();
        }
        let mut rows = self.rng.permutation(arrivals);
        rows.truncate(want.max(1));
        rows.sort_unstable();
        SelectionPlan {
            candidate_rows: Some(rows),
        }
    }

    fn select(&mut self, ctx: &SelectionContext) -> Vec<usize> {
        top_k_indices(ctx.loss, ctx.k.min(ctx.loss.len()))
    }
}

/// Historical-loss quantile used as the Selective-Backprop threshold.
const SB_QUANTILE: f32 = 0.7;
/// Select calls between threshold refreshes from the history store.
const SB_REFRESH: u64 = 16;

/// Selective-Backprop (Jiang et al., 2019), deterministic variant: keep the
/// highest-loss rows at or above a threshold τ — the `SB_QUANTILE` of the
/// historical loss distribution (`InstanceStore`), refreshed every
/// `SB_REFRESH` iterations, falling back to the in-batch quantile while no
/// history exists. Rows short of k are topped up by a seeded uniform draw
/// from the below-threshold remainder so exactly k rows always train.
pub struct SelectiveBackprop {
    rng: Pcg64,
    threshold: Option<f32>,
    calls: u64,
}

impl SelectiveBackprop {
    pub fn new(seed: u64) -> Self {
        SelectiveBackprop {
            rng: Pcg64::new(seed ^ 0x5e1b_ac99),
            threshold: None,
            calls: 0,
        }
    }

    /// Raw sampler state (checkpoint support).
    pub fn rng_words(&self) -> [u64; 4] {
        self.rng.state_words()
    }

    /// Restore sampler state captured by [`SelectiveBackprop::rng_words`].
    pub fn set_rng_words(&mut self, w: [u64; 4]) {
        self.rng = Pcg64::from_state_words(w);
    }

    /// Cached threshold + refresh counter (checkpoint support).
    pub fn threshold_state(&self) -> (Option<f32>, u64) {
        (self.threshold, self.calls)
    }

    /// Restore state captured by [`SelectiveBackprop::threshold_state`].
    pub fn set_threshold_state(&mut self, threshold: Option<f32>, calls: u64) {
        self.threshold = threshold;
        self.calls = calls;
    }

    fn in_batch_quantile(loss: &[f32]) -> f32 {
        let mut s = loss.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        s[((s.len() - 1) as f32 * SB_QUANTILE) as usize]
    }
}

impl Selector for SelectiveBackprop {
    fn name(&self) -> String {
        "selective-backprop".into()
    }

    fn select(&mut self, ctx: &SelectionContext) -> Vec<usize> {
        let b = ctx.loss.len();
        let k = ctx.k.min(b);
        if k == 0 || b == 0 {
            return Vec::new();
        }
        if self.threshold.is_none() || self.calls % SB_REFRESH == 0 {
            self.threshold = ctx
                .history
                .and_then(|h| h.loss_quantile(SB_QUANTILE))
                .or_else(|| Some(Self::in_batch_quantile(ctx.loss)));
        }
        self.calls += 1;
        let tau = self.threshold.expect("set above");
        let order = argsort_desc(ctx.loss);
        let mut out: Vec<usize> = order
            .iter()
            .copied()
            .filter(|&i| ctx.loss[i] >= tau)
            .take(k)
            .collect();
        if out.len() < k {
            // below-threshold fill keeps the contract of exactly k rows
            let below: Vec<usize> = order
                .iter()
                .copied()
                .filter(|&i| ctx.loss[i] < tau)
                .collect();
            let perm = self.rng.permutation(below.len());
            for &p in perm.iter() {
                if out.len() == k {
                    break;
                }
                out.push(below[p]);
            }
        }
        out
    }
}

/// The AdaSelection policy as a `Selector`.
pub struct AdaSelectionPolicy {
    state: AdaSelection,
    label: String,
}

impl AdaSelectionPolicy {
    pub fn new(cfg: AdaConfig) -> Self {
        let label = format!(
            "adaselection[{}]",
            cfg.candidates
                .iter()
                .map(|a| a.id())
                .collect::<Vec<_>>()
                .join("+")
        );
        AdaSelectionPolicy {
            state: AdaSelection::new(cfg),
            label,
        }
    }

    pub fn state(&self) -> &AdaSelection {
        &self.state
    }

    pub fn state_mut(&mut self) -> &mut AdaSelection {
        &mut self.state
    }

    /// Runtime path: feed kernel-computed α rows instead of recomputing.
    pub fn select_with_alphas(
        &mut self,
        loss: &[f32],
        alphas: &[Vec<f32>],
        k: usize,
    ) -> Vec<usize> {
        self.state.select_with_alphas(loss, alphas, k).selected
    }

    /// Backend-scorer path (`kernel_scorer`): the L1 scorer — the Pallas
    /// kernel on the XLA backend, `score_full` on the native backend —
    /// produced the full 7-row α matrix plus the fused scores; slice out
    /// this policy's candidates and update. Only reachable for all-kernel
    /// pools (`AdaSelection::kernel_weights` returned `Some`).
    pub fn select_kernel(
        &mut self,
        loss: &[f32],
        full_alphas: &[Vec<f32>],
        scores: Vec<f32>,
        k: usize,
    ) -> Vec<usize> {
        let cand: Vec<Vec<f32>> = self
            .state
            .config()
            .candidates
            .iter()
            .map(|a| {
                let idx = a
                    .kernel_index()
                    .expect("select_kernel called with a non-kernel arm in the pool");
                full_alphas[idx].clone()
            })
            .collect();
        self.state.select_scored(loss, &cand, scores, k).selected
    }
}

impl Selector for AdaSelectionPolicy {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn select(&mut self, ctx: &SelectionContext) -> Vec<usize> {
        self.state.step_host(ctx.loss, ctx.gnorm, ctx.k).selected
    }

    fn weights(&self) -> Option<Vec<f32>> {
        Some(self.state.weights().to_vec())
    }
}

/// Concrete policy dispatch for the trainer (avoids trait downcasts when
/// the AdaSelection kernel-scoring path needs policy internals).
pub enum Policy {
    Benchmark(BenchmarkAll),
    Single(SingleMethod),
    Obftf(ObftfPolicy),
    SelectiveBackprop(SelectiveBackprop),
    Ada(AdaSelectionPolicy),
}

impl Policy {
    pub fn name(&self) -> String {
        self.as_selector().name()
    }

    pub fn scoring(&self) -> ScoringNeeds {
        self.as_selector().scoring()
    }

    pub fn plan(&mut self, arrivals: usize, k: usize) -> SelectionPlan {
        self.as_selector_mut().plan(arrivals, k)
    }

    pub fn select(&mut self, ctx: &SelectionContext) -> Vec<usize> {
        self.as_selector_mut().select(ctx)
    }

    pub fn weights(&self) -> Option<Vec<f32>> {
        self.as_selector().weights()
    }

    fn as_selector(&self) -> &dyn Selector {
        match self {
            Policy::Benchmark(p) => p,
            Policy::Single(p) => p,
            Policy::Obftf(p) => p,
            Policy::SelectiveBackprop(p) => p,
            Policy::Ada(p) => p,
        }
    }

    fn as_selector_mut(&mut self) -> &mut dyn Selector {
        match self {
            Policy::Benchmark(p) => p,
            Policy::Single(p) => p,
            Policy::Obftf(p) => p,
            Policy::SelectiveBackprop(p) => p,
            Policy::Ada(p) => p,
        }
    }

    pub fn as_ada(&mut self) -> Option<&mut AdaSelectionPolicy> {
        match self {
            Policy::Ada(p) => Some(p),
            _ => None,
        }
    }

    pub fn as_ada_ref(&self) -> Option<&AdaSelectionPolicy> {
        match self {
            Policy::Ada(p) => Some(p),
            _ => None,
        }
    }

    /// Arm ids aligned with [`Policy::weights`] (empty for single-method
    /// policies, which report no weights).
    pub fn weight_ids(&self) -> Vec<String> {
        match self {
            Policy::Ada(p) => p
                .state()
                .config()
                .candidates
                .iter()
                .map(|m| m.id().to_string())
                .collect(),
            _ => Vec::new(),
        }
    }

    /// `(arm id, weight)` pairs for bandit policies — the telemetry view.
    pub fn weight_pairs(&self) -> Option<Vec<(String, f32)>> {
        let weights = self.weights()?;
        Some(self.weight_ids().into_iter().zip(weights).collect())
    }

    /// Build from a [`crate::config::StreamConfig`] — THE policy factory.
    /// Applies the spec grammar, the `obftf-k` knob, and the bandit rule
    /// override in one place (CLI, stream trainer, cluster nodes, and the
    /// batch trainer all route through here or a sibling below).
    pub fn from_config(cfg: &crate::config::StreamConfig) -> anyhow::Result<Policy> {
        Self::from_config_with_seed(cfg, cfg.seed)
    }

    /// [`Policy::from_config`] with an explicit seed (cluster nodes offset
    /// the config seed per node so stochastic policies decorrelate).
    pub fn from_config_with_seed(
        cfg: &crate::config::StreamConfig,
        seed: u64,
    ) -> anyhow::Result<Policy> {
        Self::from_parts(
            &cfg.selector,
            seed,
            cfg.beta,
            cfg.cl_on,
            cfg.cl_power,
            cfg.obftf_k,
            &cfg.rule,
        )
    }

    /// Build from a [`crate::config::RunConfig`] (the batch trainer). Same
    /// spec grammar and rule override; the obftf candidate multiplier
    /// stays at its default because the batch trainer scores full batches
    /// (candidate planning is a stream-path optimization).
    pub fn from_run_config(cfg: &crate::config::RunConfig) -> anyhow::Result<Policy> {
        Self::from_parts(
            &cfg.selector,
            cfg.seed,
            cfg.beta,
            cfg.cl_on,
            cfg.cl_power,
            10,
            &cfg.rule,
        )
    }

    /// Shared tail of every factory: spec grammar, then the bandit rule
    /// override (bare "eq3" keeps AdaConfig's β — the fig-7 knob; an
    /// explicit spec like "eq3:0.7" or "exp3" overrides it).
    #[allow(clippy::too_many_arguments)]
    fn from_parts(
        spec: &str,
        seed: u64,
        beta: f32,
        cl_on: bool,
        cl_power: f32,
        obftf_k: usize,
        rule: &str,
    ) -> anyhow::Result<Policy> {
        let mut policy = build_policy_full(spec, seed, beta, cl_on, cl_power, obftf_k)?;
        if rule != "eq3" {
            let rule = crate::selection::bandit::UpdateRule::parse(rule)?;
            if let Some(ada) = policy.as_ada() {
                ada.state_mut().set_rule(rule);
            }
        }
        Ok(policy)
    }
}

/// Build a [`Policy`] from a spec string with every knob explicit.
///
/// Accepted specs: `benchmark`, any registry method id (`big_loss`, …,
/// `obftf`, `selective-backprop`), `adaselection` (default pool), or
/// `adaselection:big_loss+obftf+…` to pick the pool. Unknown names error
/// with the full valid-id list.
pub fn build_policy_full(
    spec: &str,
    seed: u64,
    beta: f32,
    cl_on: bool,
    cl_power: f32,
    obftf_k: usize,
) -> anyhow::Result<Policy> {
    if spec == "benchmark" {
        return Ok(Policy::Benchmark(BenchmarkAll));
    }
    if spec == "adaselection" {
        return Ok(Policy::Ada(AdaSelectionPolicy::new(AdaConfig {
            beta,
            cl_on,
            cl_power,
            obftf_k,
            ..AdaConfig::default()
        })));
    }
    if let Some(pool) = spec.strip_prefix("adaselection:") {
        let candidates = pool
            .split('+')
            .map(Arm::from_id)
            .collect::<anyhow::Result<Vec<_>>>()?;
        anyhow::ensure!(!candidates.is_empty(), "empty adaselection pool");
        return Ok(Policy::Ada(AdaSelectionPolicy::new(AdaConfig {
            candidates,
            beta,
            cl_on,
            cl_power,
            rule: None,
            obftf_k,
        })));
    }
    match Arm::from_id(spec) {
        Ok(Arm::Kernel(m)) => Ok(Policy::Single(SingleMethod::new(m, seed))),
        Ok(Arm::Obftf) => Ok(Policy::Obftf(ObftfPolicy::new(obftf_k, seed))),
        Ok(Arm::SelectiveBackprop) => {
            Ok(Policy::SelectiveBackprop(SelectiveBackprop::new(seed)))
        }
        Err(_) => anyhow::bail!(
            "unknown selector spec '{spec}' (valid: benchmark, adaselection, \
             adaselection:<id>+<id>, {})",
            valid_method_ids().join(", ")
        ),
    }
}

/// Build a [`Policy`] from a spec string (legacy 5-knob surface; the
/// `obftf-k` multiplier takes its default of 10).
pub fn build_policy(
    spec: &str,
    seed: u64,
    beta: f32,
    cl_on: bool,
    cl_power: f32,
) -> anyhow::Result<Policy> {
    build_policy_full(spec, seed, beta, cl_on, cl_power, 10)
}

/// Build a boxed selector from its report name (config / CLI surface).
/// Same grammar as [`build_policy_full`].
pub fn build_selector(
    spec: &str,
    seed: u64,
    beta: f32,
    cl_on: bool,
    cl_power: f32,
) -> anyhow::Result<Box<dyn Selector>> {
    Ok(match build_policy(spec, seed, beta, cl_on, cl_power)? {
        Policy::Benchmark(p) => Box::new(p),
        Policy::Single(p) => Box::new(p),
        Policy::Obftf(p) => Box::new(p),
        Policy::SelectiveBackprop(p) => Box::new(p),
        Policy::Ada(p) => Box::new(p),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx<'a>(loss: &'a [f32], gnorm: &'a [f32], k: usize) -> SelectionContext<'a> {
        SelectionContext {
            loss,
            gnorm,
            k,
            history: None,
        }
    }

    #[test]
    fn benchmark_keeps_all() {
        let l = [1.0f32, 2.0, 3.0];
        let mut b = BenchmarkAll;
        assert_eq!(b.select(&ctx(&l, &l, 1)), vec![0, 1, 2]);
        assert_eq!(b.scoring(), ScoringNeeds::None);
    }

    #[test]
    fn big_small_gradnorm_semantics() {
        let loss = [0.5f32, 3.0, 1.0, 0.1];
        let gn = [2.0f32, 0.1, 0.5, 3.0];
        assert_eq!(
            SingleMethod::new(Method::BigLoss, 0).select(&ctx(&loss, &gn, 2)),
            vec![1, 2]
        );
        assert_eq!(
            SingleMethod::new(Method::SmallLoss, 0).select(&ctx(&loss, &gn, 2)),
            vec![3, 0]
        );
        assert_eq!(
            SingleMethod::new(Method::GradNorm, 0).select(&ctx(&loss, &gn, 2)),
            vec![3, 0]
        );
    }

    #[test]
    fn coreset1_takes_both_extremes() {
        let loss = [0.1f32, 0.2, 5.0, 6.0, 3.0, 3.1];
        let gn = [0.0f32; 6];
        let sel = SingleMethod::new(Method::Coreset1, 0).select(&ctx(&loss, &gn, 4));
        assert_eq!(sel.len(), 4);
        assert!(sel.contains(&3) && sel.contains(&2), "{sel:?}"); // two biggest
        assert!(sel.contains(&0) && sel.contains(&1), "{sel:?}"); // two smallest
    }

    #[test]
    fn coreset1_no_duplicates_at_full_k() {
        let loss = [1.0f32, 2.0, 3.0];
        let gn = [0.0f32; 3];
        let sel = SingleMethod::new(Method::Coreset1, 0).select(&ctx(&loss, &gn, 3));
        let mut s = sel.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 3, "{sel:?}");
    }

    #[test]
    fn coreset2_near_mean() {
        let loss = [0.0f32, 10.0, 5.0, 5.2]; // mean ≈ 5.05
        let gn = [0.0f32; 4];
        let sel = SingleMethod::new(Method::Coreset2, 0).select(&ctx(&loss, &gn, 2));
        assert_eq!(sel, vec![2, 3]);
    }

    #[test]
    fn uniform_and_adaboost_sample_k_unique() {
        let loss: Vec<f32> = (0..32).map(|i| 0.1 + i as f32 * 0.05).collect();
        let gn = vec![1.0f32; 32];
        for m in [Method::Uniform, Method::AdaBoost] {
            let sel = SingleMethod::new(m, 7).select(&ctx(&loss, &gn, 10));
            assert_eq!(sel.len(), 10, "{m:?}");
            let mut s = sel.clone();
            s.dedup();
            assert_eq!(s.len(), 10, "{m:?} dupes: {sel:?}");
        }
    }

    #[test]
    fn adaboost_sampling_biased_to_big_losses() {
        let mut big_hits = 0usize;
        let loss: Vec<f32> = (0..64)
            .map(|i| if i < 8 { 10.0 } else { 0.05 })
            .collect();
        let gn = vec![1.0f32; 64];
        let mut sm = SingleMethod::new(Method::AdaBoost, 11);
        for _ in 0..200 {
            let sel = sm.select(&ctx(&loss, &gn, 8));
            big_hits += sel.iter().filter(|&&i| i < 8).count();
        }
        // 8 of 64 rows carry nearly all weight: they must dominate picks
        assert!(big_hits > 800, "big_hits={big_hits}/1600");
    }

    #[test]
    fn obftf_plans_candidate_superset() {
        let mut p = ObftfPolicy::new(3, 42);
        assert_eq!(p.scoring(), ScoringNeeds::CandidateForward { k: 3 });
        // 3·k = 12 < 64 arrivals: a strict, sorted, unique subset
        let plan = p.plan(64, 4);
        let rows = plan.candidate_rows.expect("subset plan");
        assert_eq!(rows.len(), 12);
        assert!(rows.windows(2).all(|w| w[0] < w[1]), "{rows:?}");
        assert!(rows.iter().all(|&r| r < 64));
        // 3·k ≥ arrivals: degenerates to the full batch
        assert!(p.plan(10, 4).candidate_rows.is_none());
        // deterministic under the same seed + state
        let mut q = ObftfPolicy::new(3, 42);
        assert_eq!(q.plan(64, 4).candidate_rows.unwrap(), rows);
        // rng state survives the words round-trip
        let words = p.rng_words();
        let next = p.plan(64, 4).candidate_rows.unwrap();
        let mut r = ObftfPolicy::new(3, 1);
        r.set_rng_words(words);
        assert_eq!(r.plan(64, 4).candidate_rows.unwrap(), next);
    }

    #[test]
    fn obftf_selects_top_loss_candidates() {
        let loss = [0.5f32, 3.0, 1.0, 0.1];
        let mut p = ObftfPolicy::new(10, 0);
        assert_eq!(p.select(&ctx(&loss, &loss, 2)), vec![1, 2]);
    }

    #[test]
    fn selective_backprop_thresholds_and_fills_to_k() {
        struct FixedHist(f32);
        impl LossHistory for FixedHist {
            fn loss_quantile(&self, _q: f32) -> Option<f32> {
                Some(self.0)
            }
        }
        let loss = [0.1f32, 5.0, 0.2, 4.0, 0.3, 0.4];
        let hist = FixedHist(1.0);
        let mut sb = SelectiveBackprop::new(3);
        // two rows clear τ=1.0; k=2 keeps exactly those, biggest first
        let sel = sb.select(&SelectionContext {
            loss: &loss,
            gnorm: &loss,
            k: 2,
            history: Some(&hist),
        });
        assert_eq!(sel, vec![1, 3]);
        // k=4 needs a fill: still exactly 4 unique in-bounds rows, the two
        // above-threshold rows leading
        let sel = sb.select(&SelectionContext {
            loss: &loss,
            gnorm: &loss,
            k: 4,
            history: Some(&hist),
        });
        assert_eq!(sel.len(), 4);
        assert_eq!(&sel[..2], &[1, 3]);
        let mut s = sel.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 4, "{sel:?}");
        // no history: in-batch quantile fallback still returns k rows
        let mut sb2 = SelectiveBackprop::new(3);
        let sel = sb2.select(&ctx(&loss, &loss, 3));
        assert_eq!(sel.len(), 3);
        // determinism under the same seed + state
        let mut sb3 = SelectiveBackprop::new(3);
        assert_eq!(sb3.select(&ctx(&loss, &loss, 3)), sel);
    }

    #[test]
    fn selective_backprop_state_round_trips() {
        let loss: Vec<f32> = (0..32).map(|i| (i as f32 * 0.37).sin().abs()).collect();
        let mut a = SelectiveBackprop::new(9);
        for _ in 0..5 {
            a.select(&ctx(&loss, &loss, 30)); // large k forces rng fills
        }
        let words = a.rng_words();
        let (tau, calls) = a.threshold_state();
        let mut b = SelectiveBackprop::new(0);
        b.set_rng_words(words);
        b.set_threshold_state(tau, calls);
        for _ in 0..5 {
            assert_eq!(a.select(&ctx(&loss, &loss, 30)), b.select(&ctx(&loss, &loss, 30)));
        }
    }

    #[test]
    fn build_selector_specs() {
        assert_eq!(
            build_selector("benchmark", 0, 0.5, true, -0.5).unwrap().scoring(),
            ScoringNeeds::None
        );
        assert_eq!(
            build_selector("big_loss", 0, 0.5, true, -0.5).unwrap().name(),
            "big_loss"
        );
        assert_eq!(
            build_selector("obftf", 0, 0.5, true, -0.5).unwrap().name(),
            "obftf"
        );
        assert_eq!(
            build_selector("selective-backprop", 0, 0.5, true, -0.5)
                .unwrap()
                .name(),
            "selective-backprop"
        );
        let ada = build_selector("adaselection:big_loss+uniform", 0, 0.5, true, -0.5).unwrap();
        assert_eq!(ada.name(), "adaselection[big_loss+uniform]");
        assert_eq!(ada.weights().unwrap().len(), 2);
        // forward-cheap arms join the bandit pool
        let ada = build_selector("adaselection:big_loss+obftf+selective-backprop", 0, 0.5, true, -0.5)
            .unwrap();
        assert_eq!(ada.name(), "adaselection[big_loss+obftf+selective-backprop]");
        assert_eq!(ada.weights().unwrap().len(), 3);
        let err = build_selector("bogus", 0, 0.5, true, -0.5)
            .unwrap_err()
            .to_string();
        assert!(err.contains("obftf") && err.contains("benchmark"), "{err}");
        assert!(build_selector("adaselection:", 0, 0.5, true, -0.5).is_err());
    }
}

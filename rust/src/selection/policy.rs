//! The `Selector` abstraction the trainer drives: one implementation per
//! baseline (§3.1 semantics) plus AdaSelection and the no-sampling
//! benchmark. Policies receive per-sample losses and gnorm proxies from the
//! forward artifact and return the rows to train on.

use crate::selection::adaselection::{AdaConfig, AdaSelection};
use crate::selection::method::{adaboost_stat, dev_stat, Method};
use crate::util::rng::Pcg64;
use crate::util::topk::{bottom_k_indices, top_k_indices};

/// Inputs available to a policy at iteration t.
pub struct SelectionContext<'a> {
    /// per-sample losses over the REAL rows of the batch
    pub loss: &'a [f32],
    /// per-sample gradient-norm proxies
    pub gnorm: &'a [f32],
    /// subset size k = ceil(γ·B)
    pub k: usize,
}

/// A subsampling policy.
pub trait Selector: Send {
    /// Stable identifier used in reports (e.g. "big_loss", "adaselection").
    fn name(&self) -> String;

    /// Rows (positions within the batch) to keep, deterministic given state.
    fn select(&mut self, ctx: &SelectionContext) -> Vec<usize>;

    /// AdaSelection's method weights, if any (Fig-8 traces).
    fn weights(&self) -> Option<Vec<f32>> {
        None
    }

    /// Whether this policy skips the selection forward pass entirely
    /// (the no-sampling benchmark).
    fn is_benchmark(&self) -> bool {
        false
    }
}

/// No subsampling: keep every row (the paper's "Benchmark" column).
pub struct BenchmarkAll;

impl Selector for BenchmarkAll {
    fn name(&self) -> String {
        "benchmark".into()
    }

    fn select(&mut self, ctx: &SelectionContext) -> Vec<usize> {
        (0..ctx.loss.len()).collect()
    }

    fn is_benchmark(&self) -> bool {
        true
    }
}

/// One fixed baseline method, with the paper's §3.1 selection semantics:
/// deterministic top/bottom-k for the ranking methods, 50/50 extremes for
/// Coreset1, closest-to-mean for Coreset2, and sampling for Uniform /
/// AdaBoost (importance sampling ∝ the eq.-1 weights).
pub struct SingleMethod {
    pub method: Method,
    rng: Pcg64,
}

impl SingleMethod {
    pub fn new(method: Method, seed: u64) -> Self {
        SingleMethod {
            method,
            rng: Pcg64::new(seed ^ 0xd15e_a5e5),
        }
    }

    /// Raw sampler state (checkpoint support for the stochastic methods).
    pub fn rng_words(&self) -> [u64; 4] {
        self.rng.state_words()
    }

    /// Restore sampler state captured by [`SingleMethod::rng_words`].
    pub fn set_rng_words(&mut self, w: [u64; 4]) {
        self.rng = Pcg64::from_state_words(w);
    }

    /// Sample k distinct rows with probability ∝ weights (systematic
    /// weighted reservoir via repeated draws; k ≪ B in practice).
    fn weighted_k(&mut self, weights: &[f32], k: usize) -> Vec<usize> {
        let mut w: Vec<f64> = weights.iter().map(|&x| (x.max(0.0)) as f64 + 1e-12).collect();
        let k = k.min(w.len());
        let mut out = Vec::with_capacity(k);
        for _ in 0..k {
            let i = self.rng.weighted_index(&w);
            out.push(i);
            w[i] = 0.0;
        }
        out.sort_unstable();
        out
    }
}

impl Selector for SingleMethod {
    fn name(&self) -> String {
        self.method.name().into()
    }

    fn select(&mut self, ctx: &SelectionContext) -> Vec<usize> {
        let k = ctx.k.min(ctx.loss.len());
        match self.method {
            Method::Uniform => {
                let mut idx = self.rng.permutation(ctx.loss.len());
                idx.truncate(k);
                idx.sort_unstable();
                idx
            }
            Method::BigLoss => top_k_indices(ctx.loss, k),
            Method::SmallLoss => bottom_k_indices(ctx.loss, k),
            Method::GradNorm => top_k_indices(ctx.gnorm, k),
            Method::AdaBoost => {
                let w = adaboost_stat(ctx.loss);
                self.weighted_k(&w, k)
            }
            Method::Coreset1 => {
                // 50% biggest + 50% smallest (odd k: extra from the top)
                let top = top_k_indices(ctx.loss, k - k / 2);
                let mut bot = bottom_k_indices(ctx.loss, k / 2);
                let mut out = top;
                // avoid duplicates when k approaches B
                bot.retain(|i| !out.contains(i));
                out.append(&mut bot);
                while out.len() < k {
                    if let Some(i) = (0..ctx.loss.len()).find(|i| !out.contains(i)) {
                        out.push(i);
                    } else {
                        break;
                    }
                }
                out
            }
            Method::Coreset2 => bottom_k_indices(&dev_stat(ctx.loss), k),
        }
    }
}

/// The AdaSelection policy as a `Selector`.
pub struct AdaSelectionPolicy {
    state: AdaSelection,
    label: String,
}

impl AdaSelectionPolicy {
    pub fn new(cfg: AdaConfig) -> Self {
        let label = format!(
            "adaselection[{}]",
            cfg.candidates
                .iter()
                .map(|m| m.name())
                .collect::<Vec<_>>()
                .join("+")
        );
        AdaSelectionPolicy {
            state: AdaSelection::new(cfg),
            label,
        }
    }

    pub fn state(&self) -> &AdaSelection {
        &self.state
    }

    pub fn state_mut(&mut self) -> &mut AdaSelection {
        &mut self.state
    }

    /// Runtime path: feed kernel-computed α rows instead of recomputing.
    pub fn select_with_alphas(
        &mut self,
        loss: &[f32],
        alphas: &[Vec<f32>],
        k: usize,
    ) -> Vec<usize> {
        self.state.select_with_alphas(loss, alphas, k).selected
    }

    /// Backend-scorer path (`kernel_scorer`): the L1 scorer — the Pallas
    /// kernel on the XLA backend, `score_full` on the native backend —
    /// produced the full 7-row α matrix plus the fused scores; slice out
    /// this policy's candidates and update.
    pub fn select_kernel(
        &mut self,
        loss: &[f32],
        full_alphas: &[Vec<f32>],
        scores: Vec<f32>,
        k: usize,
    ) -> Vec<usize> {
        let cand: Vec<Vec<f32>> = self
            .state
            .config()
            .candidates
            .iter()
            .map(|m| full_alphas[m.index()].clone())
            .collect();
        self.state.select_scored(loss, &cand, scores, k).selected
    }
}

impl Selector for AdaSelectionPolicy {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn select(&mut self, ctx: &SelectionContext) -> Vec<usize> {
        self.state.step_host(ctx.loss, ctx.gnorm, ctx.k).selected
    }

    fn weights(&self) -> Option<Vec<f32>> {
        Some(self.state.weights().to_vec())
    }
}

/// Concrete policy dispatch for the trainer (avoids trait downcasts when
/// the AdaSelection kernel-scoring path needs policy internals).
pub enum Policy {
    Benchmark(BenchmarkAll),
    Single(SingleMethod),
    Ada(AdaSelectionPolicy),
}

impl Policy {
    pub fn name(&self) -> String {
        match self {
            Policy::Benchmark(p) => p.name(),
            Policy::Single(p) => p.name(),
            Policy::Ada(p) => p.name(),
        }
    }

    pub fn is_benchmark(&self) -> bool {
        matches!(self, Policy::Benchmark(_))
    }

    pub fn select(&mut self, ctx: &SelectionContext) -> Vec<usize> {
        match self {
            Policy::Benchmark(p) => p.select(ctx),
            Policy::Single(p) => p.select(ctx),
            Policy::Ada(p) => p.select(ctx),
        }
    }

    pub fn weights(&self) -> Option<Vec<f32>> {
        match self {
            Policy::Ada(p) => p.weights(),
            _ => None,
        }
    }

    pub fn as_ada(&mut self) -> Option<&mut AdaSelectionPolicy> {
        match self {
            Policy::Ada(p) => Some(p),
            _ => None,
        }
    }

    pub fn as_ada_ref(&self) -> Option<&AdaSelectionPolicy> {
        match self {
            Policy::Ada(p) => Some(p),
            _ => None,
        }
    }
}

/// Build a [`Policy`] from a spec string (same grammar as `build_selector`).
pub fn build_policy(
    spec: &str,
    seed: u64,
    beta: f32,
    cl_on: bool,
    cl_power: f32,
) -> anyhow::Result<Policy> {
    if spec == "benchmark" {
        return Ok(Policy::Benchmark(BenchmarkAll));
    }
    if let Ok(m) = Method::from_name(spec) {
        return Ok(Policy::Single(SingleMethod::new(m, seed)));
    }
    if spec == "adaselection" {
        return Ok(Policy::Ada(AdaSelectionPolicy::new(AdaConfig {
            beta,
            cl_on,
            cl_power,
            ..AdaConfig::default()
        })));
    }
    if let Some(pool) = spec.strip_prefix("adaselection:") {
        let candidates = pool
            .split('+')
            .map(Method::from_name)
            .collect::<anyhow::Result<Vec<_>>>()?;
        anyhow::ensure!(!candidates.is_empty(), "empty adaselection pool");
        return Ok(Policy::Ada(AdaSelectionPolicy::new(AdaConfig {
            candidates,
            beta,
            cl_on,
            cl_power,
            rule: None,
        })));
    }
    anyhow::bail!("unknown selector spec '{spec}'")
}

/// Build a selector from its report name (config / CLI surface).
///
/// Accepted: `benchmark`, any `Method` name, `adaselection` (default pool),
/// or `adaselection:big_loss+small_loss+uniform` to pick the pool.
pub fn build_selector(
    spec: &str,
    seed: u64,
    beta: f32,
    cl_on: bool,
    cl_power: f32,
) -> anyhow::Result<Box<dyn Selector>> {
    if spec == "benchmark" {
        return Ok(Box::new(BenchmarkAll));
    }
    if let Ok(m) = Method::from_name(spec) {
        return Ok(Box::new(SingleMethod::new(m, seed)));
    }
    if spec == "adaselection" {
        return Ok(Box::new(AdaSelectionPolicy::new(AdaConfig {
            beta,
            cl_on,
            cl_power,
            ..AdaConfig::default()
        })));
    }
    if let Some(pool) = spec.strip_prefix("adaselection:") {
        let candidates = pool
            .split('+')
            .map(Method::from_name)
            .collect::<anyhow::Result<Vec<_>>>()?;
        anyhow::ensure!(!candidates.is_empty(), "empty adaselection pool");
        return Ok(Box::new(AdaSelectionPolicy::new(AdaConfig {
            candidates,
            beta,
            cl_on,
            cl_power,
            rule: None,
        })));
    }
    anyhow::bail!("unknown selector spec '{spec}'")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx<'a>(loss: &'a [f32], gnorm: &'a [f32], k: usize) -> SelectionContext<'a> {
        SelectionContext { loss, gnorm, k }
    }

    #[test]
    fn benchmark_keeps_all() {
        let l = [1.0f32, 2.0, 3.0];
        let mut b = BenchmarkAll;
        assert_eq!(b.select(&ctx(&l, &l, 1)), vec![0, 1, 2]);
        assert!(b.is_benchmark());
    }

    #[test]
    fn big_small_gradnorm_semantics() {
        let loss = [0.5f32, 3.0, 1.0, 0.1];
        let gn = [2.0f32, 0.1, 0.5, 3.0];
        assert_eq!(
            SingleMethod::new(Method::BigLoss, 0).select(&ctx(&loss, &gn, 2)),
            vec![1, 2]
        );
        assert_eq!(
            SingleMethod::new(Method::SmallLoss, 0).select(&ctx(&loss, &gn, 2)),
            vec![3, 0]
        );
        assert_eq!(
            SingleMethod::new(Method::GradNorm, 0).select(&ctx(&loss, &gn, 2)),
            vec![3, 0]
        );
    }

    #[test]
    fn coreset1_takes_both_extremes() {
        let loss = [0.1f32, 0.2, 5.0, 6.0, 3.0, 3.1];
        let gn = [0.0f32; 6];
        let sel = SingleMethod::new(Method::Coreset1, 0).select(&ctx(&loss, &gn, 4));
        assert_eq!(sel.len(), 4);
        assert!(sel.contains(&3) && sel.contains(&2), "{sel:?}"); // two biggest
        assert!(sel.contains(&0) && sel.contains(&1), "{sel:?}"); // two smallest
    }

    #[test]
    fn coreset1_no_duplicates_at_full_k() {
        let loss = [1.0f32, 2.0, 3.0];
        let gn = [0.0f32; 3];
        let sel = SingleMethod::new(Method::Coreset1, 0).select(&ctx(&loss, &gn, 3));
        let mut s = sel.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 3, "{sel:?}");
    }

    #[test]
    fn coreset2_near_mean() {
        let loss = [0.0f32, 10.0, 5.0, 5.2]; // mean ≈ 5.05
        let gn = [0.0f32; 4];
        let sel = SingleMethod::new(Method::Coreset2, 0).select(&ctx(&loss, &gn, 2));
        assert_eq!(sel, vec![2, 3]);
    }

    #[test]
    fn uniform_and_adaboost_sample_k_unique() {
        let loss: Vec<f32> = (0..32).map(|i| 0.1 + i as f32 * 0.05).collect();
        let gn = vec![1.0f32; 32];
        for m in [Method::Uniform, Method::AdaBoost] {
            let sel = SingleMethod::new(m, 7).select(&ctx(&loss, &gn, 10));
            assert_eq!(sel.len(), 10, "{m:?}");
            let mut s = sel.clone();
            s.dedup();
            assert_eq!(s.len(), 10, "{m:?} dupes: {sel:?}");
        }
    }

    #[test]
    fn adaboost_sampling_biased_to_big_losses() {
        let mut big_hits = 0usize;
        let loss: Vec<f32> = (0..64)
            .map(|i| if i < 8 { 10.0 } else { 0.05 })
            .collect();
        let gn = vec![1.0f32; 64];
        let mut sm = SingleMethod::new(Method::AdaBoost, 11);
        for _ in 0..200 {
            let sel = sm.select(&ctx(&loss, &gn, 8));
            big_hits += sel.iter().filter(|&&i| i < 8).count();
        }
        // 8 of 64 rows carry nearly all weight: they must dominate picks
        assert!(big_hits > 800, "big_hits={big_hits}/1600");
    }

    #[test]
    fn build_selector_specs() {
        assert!(build_selector("benchmark", 0, 0.5, true, -0.5).unwrap().is_benchmark());
        assert_eq!(
            build_selector("big_loss", 0, 0.5, true, -0.5).unwrap().name(),
            "big_loss"
        );
        let ada = build_selector("adaselection:big_loss+uniform", 0, 0.5, true, -0.5).unwrap();
        assert_eq!(ada.name(), "adaselection[big_loss+uniform]");
        assert_eq!(ada.weights().unwrap().len(), 2);
        assert!(build_selector("bogus", 0, 0.5, true, -0.5).is_err());
        assert!(build_selector("adaselection:", 0, 0.5, true, -0.5).is_err());
    }
}

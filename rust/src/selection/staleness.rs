//! Stale-loss forward approximation (the paper's §5 future-work item:
//! "a forward pass approximation can be used instead to determine data-wise
//! importance").
//!
//! The selection forward pass costs ≈ fwd(B) every iteration even though
//! per-sample losses drift slowly. [`LossCache`] keeps the last observed
//! (loss, gnorm) per *dataset index* and an age counter; when every sample
//! in a batch has a cached value younger than `refresh_every` epochs, the
//! trainer can skip the forward pass entirely and select on cached values,
//! cutting method cost from `fwd(B) + train(K)` toward `train(K)`.
//!
//! Since the streaming subsystem landed, `LossCache` is a thin compat shim
//! over the sharded [`InstanceStore`] (one bounded statistics store shared
//! by the batch trainer and the stream trainer) keyed by dataset index;
//! the old per-`Vec` entry table is gone. Epochs play the role of the
//! store's tick, and the batch-level hit/miss accounting (cache-served vs
//! forward-pass batches) lives here, on top of the store's per-instance
//! counters.
//!
//! The ablation bench (`ablate-stale`) quantifies the speed/quality trade.

use crate::stream::store::InstanceStore;

/// Cache of per-sample selection statistics keyed by dataset index.
pub struct LossCache {
    store: InstanceStore,
    /// reuse cached stats for batches whose entries are at most this many
    /// epochs old; 0 disables reuse entirely
    pub refresh_every: u32,
    hits: u64,
    misses: u64,
}

impl LossCache {
    pub fn new(n_samples: usize, refresh_every: u32) -> Self {
        // capacity 4× the dataset: epoch-indexed access never hits the
        // generational eviction bound, so lookups after a fresh
        // can_skip_forward always find their record. With the feature
        // disabled (refresh_every == 0) nothing is ever stored, so the
        // allocation collapses to the shard floor.
        let capacity = if refresh_every == 0 {
            1
        } else {
            (4 * n_samples.max(1)).max(64)
        };
        LossCache {
            store: InstanceStore::new(capacity, 8),
            refresh_every,
            hits: 0,
            misses: 0,
        }
    }

    /// Can this batch be selected from cache alone at `epoch`?
    pub fn can_skip_forward(&mut self, indices: &[usize], epoch: usize) -> bool {
        if self.refresh_every == 0 {
            return false;
        }
        let ok = indices.iter().all(|&i| match self.store.peek(i as u64) {
            Some(r) => (epoch as u32).saturating_sub(r.last_tick) <= self.refresh_every,
            None => false,
        });
        if ok {
            self.hits += 1;
        } else {
            self.misses += 1;
        }
        ok
    }

    /// Read cached (loss, gnorm) rows for a batch (zeros for never-seen
    /// indices — callers gate on [`LossCache::can_skip_forward`]).
    pub fn lookup(&self, indices: &[usize]) -> (Vec<f32>, Vec<f32>) {
        let mut loss = Vec::with_capacity(indices.len());
        let mut gnorm = Vec::with_capacity(indices.len());
        for &i in indices {
            match self.store.peek(i as u64) {
                Some(r) => {
                    loss.push(r.loss);
                    gnorm.push(r.gnorm);
                }
                None => {
                    loss.push(0.0);
                    gnorm.push(0.0);
                }
            }
        }
        (loss, gnorm)
    }

    /// Store fresh forward results for a batch. A no-op when the feature
    /// is disabled (`refresh_every == 0`): nothing would ever read the
    /// records, so the batch trainer's hot path skips the per-sample
    /// shard-lock/hash/upsert entirely.
    pub fn update(&mut self, indices: &[usize], loss: &[f32], gnorm: &[f32], epoch: usize) {
        if self.refresh_every == 0 {
            return;
        }
        for ((&i, &l), &g) in indices.iter().zip(loss.iter()).zip(gnorm.iter()) {
            self.store.update(i as u64, l, g, epoch as u32);
        }
    }

    /// (cache-served batches, forward-pass batches) so far.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }

    /// Fraction of batches served from cache.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// The backing instance store (per-instance counters, footprint).
    pub fn store(&self) -> &InstanceStore {
        &self.store
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cold_cache_never_skips() {
        let mut c = LossCache::new(10, 2);
        assert!(!c.can_skip_forward(&[0, 1, 2], 0));
        assert_eq!(c.stats(), (0, 1));
    }

    #[test]
    fn warm_cache_skips_within_window() {
        let mut c = LossCache::new(10, 2);
        c.update(&[0, 1, 2], &[1.0, 2.0, 3.0], &[0.1, 0.2, 0.3], 0);
        assert!(c.can_skip_forward(&[0, 1, 2], 1)); // age 1 ≤ 2
        assert!(c.can_skip_forward(&[2, 0], 2)); // age 2 ≤ 2
        assert!(!c.can_skip_forward(&[0, 1], 3)); // age 3 > 2
    }

    #[test]
    fn partial_coverage_blocks_skip() {
        let mut c = LossCache::new(10, 5);
        c.update(&[0, 1], &[1.0, 2.0], &[0.1, 0.2], 0);
        assert!(!c.can_skip_forward(&[0, 1, 2], 1)); // 2 never seen
    }

    #[test]
    fn lookup_returns_stored_rows() {
        let mut c = LossCache::new(5, 1);
        c.update(&[3, 1], &[9.0, 7.0], &[0.9, 0.7], 0);
        let (l, g) = c.lookup(&[1, 3]);
        assert_eq!(l, vec![7.0, 9.0]);
        assert_eq!(g, vec![0.7, 0.9]);
    }

    #[test]
    fn refresh_zero_disables() {
        let mut c = LossCache::new(4, 0);
        c.update(&[0, 1, 2, 3], &[1.0; 4], &[1.0; 4], 0);
        assert!(!c.can_skip_forward(&[0, 1], 0));
    }

    #[test]
    fn hit_rate_accounts() {
        let mut c = LossCache::new(4, 10);
        c.update(&[0, 1], &[1.0, 1.0], &[1.0, 1.0], 0);
        let _ = c.can_skip_forward(&[0, 1], 1); // hit
        let _ = c.can_skip_forward(&[2, 3], 1); // miss
        assert!((c.hit_rate() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn shares_the_instance_store_substrate() {
        // full-dataset epochs never evict: the shim's capacity headroom
        // keeps every index live across refreshes
        let n = 500;
        let mut c = LossCache::new(n, 2);
        let indices: Vec<usize> = (0..n).collect();
        let loss = vec![1.0f32; n];
        let gnorm = vec![0.5f32; n];
        for epoch in 0..4 {
            c.update(&indices, &loss, &gnorm, epoch);
        }
        assert_eq!(c.store().len(), n);
        assert_eq!(c.store().counters().evictions, 0);
        let r = c.store().peek(7).unwrap();
        assert_eq!(r.visits, 4);
        assert_eq!(r.last_tick, 3);
    }
}

//! Stale-loss forward approximation (the paper's §5 future-work item:
//! "a forward pass approximation can be used instead to determine data-wise
//! importance").
//!
//! The selection forward pass costs ≈ fwd(B) every iteration even though
//! per-sample losses drift slowly. [`LossCache`] keeps the last observed
//! (loss, gnorm) per *dataset index* and an age counter; when every sample
//! in a batch has a cached value younger than `refresh_every` epochs, the
//! trainer can skip the forward pass entirely and select on cached values,
//! cutting method cost from `fwd(B) + train(K)` toward `train(K)`.
//!
//! The ablation bench (`ablate-stale`) quantifies the speed/quality trade.

/// Per-sample cached statistics.
#[derive(Clone, Copy, Debug)]
struct Entry {
    loss: f32,
    gnorm: f32,
    /// epoch at which this entry was written (u32::MAX = never)
    epoch: u32,
}

/// Cache of per-sample selection statistics keyed by dataset index.
#[derive(Clone, Debug)]
pub struct LossCache {
    entries: Vec<Entry>,
    /// reuse cached stats for batches whose entries are at most this many
    /// epochs old; 0 disables reuse entirely
    pub refresh_every: u32,
    hits: u64,
    misses: u64,
}

impl LossCache {
    pub fn new(n_samples: usize, refresh_every: u32) -> Self {
        LossCache {
            entries: vec![
                Entry {
                    loss: 0.0,
                    gnorm: 0.0,
                    epoch: u32::MAX,
                };
                n_samples
            ],
            refresh_every,
            hits: 0,
            misses: 0,
        }
    }

    /// Can this batch be selected from cache alone at `epoch`?
    pub fn can_skip_forward(&mut self, indices: &[usize], epoch: usize) -> bool {
        if self.refresh_every == 0 {
            return false;
        }
        let ok = indices.iter().all(|&i| {
            let e = self.entries[i].epoch;
            e != u32::MAX && (epoch as u32).saturating_sub(e) <= self.refresh_every
        });
        if ok {
            self.hits += 1;
        } else {
            self.misses += 1;
        }
        ok
    }

    /// Read cached (loss, gnorm) rows for a batch.
    pub fn lookup(&self, indices: &[usize]) -> (Vec<f32>, Vec<f32>) {
        (
            indices.iter().map(|&i| self.entries[i].loss).collect(),
            indices.iter().map(|&i| self.entries[i].gnorm).collect(),
        )
    }

    /// Store fresh forward results for a batch.
    pub fn update(&mut self, indices: &[usize], loss: &[f32], gnorm: &[f32], epoch: usize) {
        for ((&i, &l), &g) in indices.iter().zip(loss.iter()).zip(gnorm.iter()) {
            self.entries[i] = Entry {
                loss: l,
                gnorm: g,
                epoch: epoch as u32,
            };
        }
    }

    /// (cache-served batches, forward-pass batches) so far.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }

    /// Fraction of batches served from cache.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cold_cache_never_skips() {
        let mut c = LossCache::new(10, 2);
        assert!(!c.can_skip_forward(&[0, 1, 2], 0));
        assert_eq!(c.stats(), (0, 1));
    }

    #[test]
    fn warm_cache_skips_within_window() {
        let mut c = LossCache::new(10, 2);
        c.update(&[0, 1, 2], &[1.0, 2.0, 3.0], &[0.1, 0.2, 0.3], 0);
        assert!(c.can_skip_forward(&[0, 1, 2], 1)); // age 1 ≤ 2
        assert!(c.can_skip_forward(&[2, 0], 2)); // age 2 ≤ 2
        assert!(!c.can_skip_forward(&[0, 1], 3)); // age 3 > 2
    }

    #[test]
    fn partial_coverage_blocks_skip() {
        let mut c = LossCache::new(10, 5);
        c.update(&[0, 1], &[1.0, 2.0], &[0.1, 0.2], 0);
        assert!(!c.can_skip_forward(&[0, 1, 2], 1)); // 2 never seen
    }

    #[test]
    fn lookup_returns_stored_rows() {
        let mut c = LossCache::new(5, 1);
        c.update(&[3, 1], &[9.0, 7.0], &[0.9, 0.7], 0);
        let (l, g) = c.lookup(&[1, 3]);
        assert_eq!(l, vec![7.0, 9.0]);
        assert_eq!(g, vec![0.7, 0.9]);
    }

    #[test]
    fn refresh_zero_disables() {
        let mut c = LossCache::new(4, 0);
        c.update(&[0, 1, 2, 3], &[1.0; 4], &[1.0; 4], 0);
        assert!(!c.can_skip_forward(&[0, 1], 0));
    }

    #[test]
    fn hit_rate_accounts() {
        let mut c = LossCache::new(4, 10);
        c.update(&[0, 1], &[1.0, 1.0], &[1.0, 1.0], 0);
        let _ = c.can_skip_forward(&[0, 1], 1); // hit
        let _ = c.can_skip_forward(&[2, 3], 1); // miss
        assert!((c.hit_rate() - 0.5).abs() < 1e-9);
    }
}

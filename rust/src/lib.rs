//! # AdaSelection
//!
//! A rust + JAX/Pallas reproduction of *"AdaSelection: Accelerating Deep
//! Learning Training through Data Subsampling"* (2023).
//!
//! Architecture (three layers, python never on the request path):
//!   * **L3 (this crate)** — streaming data pipeline, the AdaSelection
//!     policy + seven baseline subsampling methods, the batch trainer, the
//!     continuous-training [`stream`] subsystem (unbounded epochless
//!     sources + sharded bounded instance store + drift-adaptive γ +
//!     replay + checkpoint/resume), the multi-node [`cluster`] subsystem
//!     (consistent-hash sharding, loopback + TCP socket transports over a
//!     checksummed wire format, full/delta store gossip, model/policy
//!     merge, kill/join churn), metrics, and the experiment harness
//!     reproducing every paper table/figure.
//!   * **L2 (python/compile)** — JAX model graphs (MLP / mini-ResNet /
//!     Transformer) lowered once to HLO text by `make artifacts`.
//!   * **L1 (python/compile/kernels)** — Pallas kernels for per-sample
//!     losses, grad-norm proxies and the fused AdaSelection scorer, baked
//!     into the same HLO modules.
//!
//! ## Backends: L1-native vs L1-Pallas
//!
//! The trainer drives everything through [`runtime::Backend`], which has
//! two implementations of the same L1 kernel math:
//!
//!   * **L1-native** ([`runtime::NativeBackend`], the default) — pure-Rust
//!     ports of the reference kernels in `python/compile/kernels/ref.py`
//!     (per-sample losses, grad-norm proxies, the fused AdaSelection
//!     scorer) plus SGD+momentum train steps. No Python, no XLA shared
//!     library, no artifacts directory; any subset size trains, so ⌈γB⌉ is
//!     exact. This is the backend CI builds and tests on bare runners, and
//!     the CPU-only deployment path.
//!   * **L1-Pallas** ([`runtime::Engine`], behind `--features xla`) — the
//!     PJRT engine executing the Pallas-backed HLO artifacts produced by
//!     `make artifacts`; the perf path on real accelerators.
//!
//! Both scorers are the same math ([`selection::adaselection::score_full`]
//! is the shared oracle), so selection trajectories agree across backends.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for results.

pub mod cli;
pub mod cluster;
pub mod config;
pub mod data;
pub mod harness;
pub mod metrics;
pub mod obs;
pub mod pipeline;
pub mod runtime;
pub mod selection;
pub mod stream;
pub mod testutil;
pub mod train;
pub mod util;

//! # AdaSelection
//!
//! A rust + JAX/Pallas reproduction of *"AdaSelection: Accelerating Deep
//! Learning Training through Data Subsampling"* (2023).
//!
//! Architecture (three layers, python never on the request path):
//!   * **L3 (this crate)** — streaming data pipeline, the AdaSelection
//!     policy + seven baseline subsampling methods, trainer, metrics, and
//!     the experiment harness reproducing every paper table/figure.
//!   * **L2 (python/compile)** — JAX model graphs (MLP / mini-ResNet /
//!     Transformer) lowered once to HLO text by `make artifacts`.
//!   * **L1 (python/compile/kernels)** — Pallas kernels for per-sample
//!     losses, grad-norm proxies and the fused AdaSelection scorer, baked
//!     into the same HLO modules.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for results.

pub mod cli;
pub mod config;
pub mod data;
pub mod harness;
pub mod metrics;
pub mod pipeline;
pub mod runtime;
pub mod selection;
pub mod testutil;
pub mod train;
pub mod util;

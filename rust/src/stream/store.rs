//! Sharded, bounded-memory per-instance statistics store.
//!
//! The paper's continuous-training scenario works because AdaSelection only
//! needs "a constant amount of information per instance". This store is
//! that constant record, made concrete: a fixed [`InstanceRecord`]
//! (loss, gnorm proxy, last-seen tick, visit count) keyed by a `u64`
//! sample id, held in N mutex-sharded segments so the stream trainer and
//! diagnostics can touch it concurrently without a global lock.
//!
//! Memory is *hard*-bounded by generational eviction: each shard keeps two
//! generations of at most `capacity / (2·shards)` records. Inserting into a
//! full current generation rotates — the previous old generation is dropped
//! wholesale (its size is added to the evict counter), the current one
//! becomes old, and a fresh current generation starts. Lookups check both
//! generations and promote hits, so recently-touched instances survive
//! rotations while stale ones age out in O(1) amortized time. Total live
//! records never exceed `capacity` (rounded up to `2·shards`).
//!
//! This generalizes and absorbs the old `selection::staleness::LossCache`
//! per-`Vec` cache — the batch trainer now rides on the same store through
//! a thin compat shim (see `selection::staleness`).
//!
//! For the cluster, the store is also the gossip substrate: `merge` folds
//! a peer's entries in freshest-tick-wins, and opt-in dirty tracking
//! (`enable_dirty_tracking` / `take_dirty`) hands delta gossip exactly the
//! entries touched locally since the last sync instead of full snapshots.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

/// Fixed per-instance statistics record ("constant information per
/// instance").
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct InstanceRecord {
    /// last observed per-sample loss
    pub loss: f32,
    /// last observed gradient-norm proxy
    pub gnorm: f32,
    /// tick (stream) / epoch (batch trainer) of the last observation
    pub last_tick: u32,
    /// how many times this instance has been observed
    pub visits: u32,
}

/// Bytes of payload per stored instance (key + record), the store's
/// bounded-memory unit.
pub const BYTES_PER_INSTANCE: usize =
    std::mem::size_of::<u64>() + std::mem::size_of::<InstanceRecord>();

/// Monotonic store counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreCounters {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

#[derive(Default)]
struct Shard {
    cur: HashMap<u64, InstanceRecord>,
    old: HashMap<u64, InstanceRecord>,
    /// ids touched by `update` since the last [`InstanceStore::take_dirty`]
    /// / [`InstanceStore::clear_dirty`] — the delta-gossip send set. Only
    /// populated when dirty tracking is enabled.
    dirty: HashSet<u64>,
}

/// The sharded bounded store. All methods take `&self` (interior
/// mutability via per-shard mutexes + atomic counters), so the store can be
/// shared across threads without an outer lock.
pub struct InstanceStore {
    shards: Vec<Mutex<Shard>>,
    /// per-shard, per-generation record budget
    gen_capacity: usize,
    /// configured total capacity (hard bound on live records)
    capacity: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    /// eviction count at the last gossip sync — the delta between this
    /// and `evictions` is the cluster's escalate-to-full signal
    evictions_at_sync: AtomicU64,
    /// opt-in (cluster delta gossip): off by default so stores that never
    /// sync don't accumulate an unbounded dirty set
    track_dirty: AtomicBool,
}

/// SplitMix-style avalanche so sequential ids spread across shards.
fn mix(id: u64) -> u64 {
    crate::util::rng::avalanche(id.wrapping_add(0x9e37_79b9_7f4a_7c15))
}

impl InstanceStore {
    /// A store holding at most `capacity` records across `n_shards`
    /// segments. `capacity` is rounded up to `2·n_shards` so every shard
    /// fits at least one record per generation.
    pub fn new(capacity: usize, n_shards: usize) -> InstanceStore {
        let n = n_shards.max(1);
        let capacity = capacity.max(2 * n);
        let gen_capacity = (capacity / (2 * n)).max(1);
        InstanceStore {
            shards: (0..n).map(|_| Mutex::new(Shard::default())).collect(),
            gen_capacity,
            capacity: gen_capacity * 2 * n,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            evictions_at_sync: AtomicU64::new(0),
            track_dirty: AtomicBool::new(false),
        }
    }

    fn shard(&self, id: u64) -> &Mutex<Shard> {
        &self.shards[(mix(id) as usize) % self.shards.len()]
    }

    /// Insert into the current generation, rotating generations when full.
    fn insert_cur(&self, s: &mut Shard, id: u64, rec: InstanceRecord) {
        if !s.cur.contains_key(&id) && s.cur.len() >= self.gen_capacity {
            let dropped = std::mem::replace(&mut s.old, std::mem::take(&mut s.cur));
            self.evictions.fetch_add(dropped.len() as u64, Ordering::Relaxed);
        }
        s.cur.insert(id, rec);
    }

    /// Read without touching counters or generations (diagnostics and the
    /// staleness shim's freshness probe).
    pub fn peek(&self, id: u64) -> Option<InstanceRecord> {
        let s = self.shard(id).lock().unwrap();
        s.cur.get(&id).or_else(|| s.old.get(&id)).copied()
    }

    /// Counted lookup: hits promote old-generation records into the
    /// current generation so hot instances survive rotations.
    pub fn get(&self, id: u64) -> Option<InstanceRecord> {
        let mut s = self.shard(id).lock().unwrap();
        if let Some(r) = s.cur.get(&id).copied() {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Some(r);
        }
        if let Some(r) = s.old.remove(&id) {
            self.insert_cur(&mut s, id, r);
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Some(r);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        None
    }

    /// Upsert fresh statistics for one instance; `visits` carries over from
    /// any live record of the same id.
    pub fn update(&self, id: u64, loss: f32, gnorm: f32, tick: u32) {
        let mut s = self.shard(id).lock().unwrap();
        let prev = s.cur.get(&id).copied().or_else(|| s.old.remove(&id));
        let rec = InstanceRecord {
            loss,
            gnorm,
            last_tick: tick,
            visits: prev.map(|p| p.visits).unwrap_or(0).saturating_add(1),
        };
        self.insert_cur(&mut s, id, rec);
        if self.track_dirty.load(Ordering::Relaxed) {
            s.dirty.insert(id);
        }
    }

    /// Start tracking the ids [`InstanceStore::update`] touches, so
    /// [`InstanceStore::take_dirty`] can hand delta gossip only the
    /// entries changed since the last sync. Gossip merged from peers
    /// ([`InstanceStore::merge`]) is deliberately *not* marked — in a
    /// full-mesh broadcast every peer heard the origin directly, so
    /// re-forwarding would only echo.
    pub fn enable_dirty_tracking(&self) {
        self.track_dirty.store(true, Ordering::Relaxed);
    }

    /// Live records locally touched since the last take/clear, sorted by
    /// id (deterministic), clearing the dirty marks. Ids evicted since
    /// they were touched are skipped — a peer could not use them anyway.
    pub fn take_dirty(&self) -> Vec<(u64, InstanceRecord)> {
        let mut out: Vec<(u64, InstanceRecord)> = Vec::new();
        for shard in &self.shards {
            let mut s = shard.lock().unwrap();
            let dirty = std::mem::take(&mut s.dirty);
            for id in dirty {
                if let Some(r) = s.cur.get(&id).copied().or_else(|| s.old.get(&id).copied()) {
                    out.push((id, r));
                }
            }
        }
        out.sort_unstable_by_key(|&(id, _)| id);
        out
    }

    /// Drop all pending dirty marks without reading them (called after a
    /// full snapshot went out — everything live has just been shared).
    pub fn clear_dirty(&self) {
        for shard in &self.shards {
            shard.lock().unwrap().dirty.clear();
        }
    }

    /// Whether any shard evicted records since the last
    /// [`InstanceStore::mark_gossip_synced`]. A delta gossip cannot
    /// restore entries a *receiver* evicted (a full snapshot can), so
    /// cluster coordinators escalate a delta round to full whenever any
    /// live node reports this — the rule that keeps tcp+delta runs
    /// bit-identical to loopback+full under eviction pressure.
    pub fn evicted_since_sync(&self) -> bool {
        self.evictions.load(Ordering::Relaxed)
            != self.evictions_at_sync.load(Ordering::Relaxed)
    }

    /// Record the current eviction count as the gossip-sync baseline;
    /// called when a gossip payload is built (delta or full).
    pub fn mark_gossip_synced(&self) {
        self.evictions_at_sync
            .store(self.evictions.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Live records across all shards and both generations.
    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| {
                let s = s.lock().unwrap();
                s.cur.len() + s.old.len()
            })
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The hard bound [`InstanceStore::len`] never exceeds.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Current payload footprint in bytes (`len · BYTES_PER_INSTANCE`).
    pub fn approx_bytes(&self) -> usize {
        self.len() * BYTES_PER_INSTANCE
    }

    /// Fill fraction `len / capacity` in `[0, 1]` — the "store pressure"
    /// number the status endpoint and trace journal report.
    pub fn pressure(&self) -> f64 {
        self.len() as f64 / self.capacity.max(1) as f64
    }

    pub fn counters(&self) -> StoreCounters {
        StoreCounters {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
        }
    }

    /// All live records in shard-internal (nondeterministic) order.
    fn live_records(&self) -> Vec<(u64, InstanceRecord)> {
        let mut out: Vec<(u64, InstanceRecord)> = Vec::with_capacity(self.len());
        for s in &self.shards {
            let s = s.lock().unwrap();
            out.extend(s.old.iter().map(|(&id, &r)| (id, r)));
            out.extend(s.cur.iter().map(|(&id, &r)| (id, r)));
        }
        out
    }

    /// All live records, sorted by id (deterministic checkpoint payload).
    pub fn snapshot(&self) -> Vec<(u64, InstanceRecord)> {
        let mut out = self.live_records();
        out.sort_unstable_by_key(|&(id, _)| id);
        out
    }

    /// Re-insert checkpointed records (visit counts preserved verbatim).
    /// Everything lands in the current generation, so a resumed store
    /// re-ages from scratch — exact generational placement comes from
    /// [`InstanceStore::load_with_generations`].
    pub fn load(&self, entries: &[(u64, InstanceRecord)]) {
        for &(id, rec) in entries {
            let mut s = self.shard(id).lock().unwrap();
            s.old.remove(&id);
            self.insert_cur(&mut s, id, rec);
        }
    }

    /// Like [`InstanceStore::snapshot`], plus the sorted ids of the
    /// old-generation members — the checkpoint v4 payload. Membership is
    /// all generational placement needs: shard assignment is a pure
    /// function of the id and rotation drops whole generations, so the
    /// cur/old split fully determines future eviction behavior.
    pub fn snapshot_with_generations(&self) -> (Vec<(u64, InstanceRecord)>, Vec<u64>) {
        let mut old_ids: Vec<u64> = Vec::new();
        for s in &self.shards {
            let s = s.lock().unwrap();
            old_ids.extend(s.old.keys().copied());
        }
        old_ids.sort_unstable();
        (self.snapshot(), old_ids)
    }

    /// Restore a checkpoint with exact generational placement: entries
    /// whose ids appear in `old_ids` land in the old generation, the
    /// rest in the current one, bit-for-bit reproducing the saver's
    /// rotation state. Returns `true` on exact placement. When the split
    /// does not fit this store's shard geometry (a resume under a
    /// different `--store-capacity`/`--store-shards`), falls back to
    /// [`InstanceStore::load`] — the resume still works, the store just
    /// re-ages like the v3 checkpoint format always did.
    pub fn load_with_generations(
        &self,
        entries: &[(u64, InstanceRecord)],
        old_ids: &[u64],
    ) -> bool {
        let old: HashSet<u64> = old_ids.iter().copied().collect();
        let n = self.shards.len();
        let mut cur_count = vec![0usize; n];
        let mut old_count = vec![0usize; n];
        for &(id, _) in entries {
            let shard = (mix(id) as usize) % n;
            if old.contains(&id) {
                old_count[shard] += 1;
            } else {
                cur_count[shard] += 1;
            }
        }
        let fits = cur_count
            .iter()
            .chain(old_count.iter())
            .all(|&c| c <= self.gen_capacity);
        if !fits {
            self.load(entries);
            return false;
        }
        for &(id, rec) in entries {
            let mut s = self.shard(id).lock().unwrap();
            if old.contains(&id) {
                s.cur.remove(&id);
                s.old.insert(id, rec);
            } else {
                s.old.remove(&id);
                s.cur.insert(id, rec);
            }
        }
        true
    }

    /// Merge a peer store's snapshot (cluster gossip): freshest-tick-wins
    /// per id, resident record kept on ties. The incoming record lands in
    /// the current generation, so capacity stays hard-bounded through the
    /// usual generational eviction.
    pub fn merge(&self, entries: &[(u64, InstanceRecord)]) {
        for &(id, rec) in entries {
            let mut s = self.shard(id).lock().unwrap();
            let resident = s.cur.get(&id).copied().or_else(|| s.old.get(&id).copied());
            if let Some(r) = resident {
                if r.last_tick >= rec.last_tick {
                    continue;
                }
            }
            s.old.remove(&id);
            self.insert_cur(&mut s, id, rec);
        }
    }

    /// The `n` live records with the largest losses (ties broken by id),
    /// skipping ids in `exclude` — the replay scheduler's pick list.
    /// Partitioning before sorting keeps the hot lull-tick path at
    /// O(live + n log n) instead of fully sorting the store; the (loss,
    /// id) total order makes the result deterministic regardless of
    /// shard-iteration order.
    pub fn top_by_loss(
        &self,
        n: usize,
        exclude: &std::collections::HashSet<u64>,
    ) -> Vec<(u64, InstanceRecord)> {
        if n == 0 {
            return Vec::new();
        }
        let cmp = |a: &(u64, InstanceRecord), b: &(u64, InstanceRecord)| {
            b.1.loss
                .partial_cmp(&a.1.loss)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.0.cmp(&b.0))
        };
        let mut all = self.live_records();
        all.retain(|(id, _)| !exclude.contains(id));
        if all.len() > n {
            all.select_nth_unstable_by(n - 1, cmp);
            all.truncate(n);
        }
        all.sort_unstable_by(cmp);
        all
    }

    /// The q-quantile (q ∈ [0, 1]) of live losses, or `None` when empty.
    /// Sorting by (loss, id) makes the pick deterministic regardless of
    /// shard-iteration order — the selective-backprop threshold source.
    pub fn loss_quantile(&self, q: f32) -> Option<f32> {
        let mut all = self.live_records();
        if all.is_empty() {
            return None;
        }
        all.sort_unstable_by(|a, b| {
            a.1.loss
                .partial_cmp(&b.1.loss)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.0.cmp(&b.0))
        });
        let q = q.clamp(0.0, 1.0);
        let idx = ((all.len() - 1) as f32 * q) as usize;
        Some(all[idx].1.loss)
    }
}

impl crate::selection::policy::LossHistory for InstanceStore {
    fn loss_quantile(&self, q: f32) -> Option<f32> {
        InstanceStore::loss_quantile(self, q)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loss_quantile_is_deterministic_and_ordered() {
        let store = InstanceStore::new(128, 4);
        assert_eq!(store.loss_quantile(0.5), None);
        for id in 0..10u64 {
            store.update(id, id as f32, 0.1, 1);
        }
        assert_eq!(store.loss_quantile(0.0), Some(0.0));
        assert_eq!(store.loss_quantile(1.0), Some(9.0));
        // index (10-1)*0.7 = 6.3 → floor 6
        assert_eq!(store.loss_quantile(0.7), Some(6.0));
        // out-of-range q clamps
        assert_eq!(store.loss_quantile(7.0), Some(9.0));
    }

    #[test]
    fn round_trips_records() {
        let store = InstanceStore::new(128, 4);
        store.update(7, 1.5, 0.3, 2);
        store.update(7, 2.5, 0.4, 3);
        let r = store.get(7).unwrap();
        assert_eq!(r.loss, 2.5);
        assert_eq!(r.gnorm, 0.4);
        assert_eq!(r.last_tick, 3);
        assert_eq!(r.visits, 2);
        assert_eq!(store.len(), 1);
        assert!(store.get(8).is_none());
        let c = store.counters();
        assert_eq!((c.hits, c.misses), (1, 1));
    }

    #[test]
    fn capacity_is_a_hard_bound() {
        let store = InstanceStore::new(64, 4);
        assert_eq!(store.capacity(), 64);
        for id in 0..10_000u64 {
            store.update(id, 0.1, 0.1, (id / 100) as u32);
            assert!(store.len() <= store.capacity(), "len {} at id {id}", store.len());
        }
        let c = store.counters();
        assert!(c.evictions > 0);
        // everything inserted is either live or counted evicted
        assert_eq!(c.evictions + store.len() as u64, 10_000);
        assert!(store.approx_bytes() <= store.capacity() * BYTES_PER_INSTANCE);
    }

    #[test]
    fn hot_entries_survive_rotations() {
        // single shard, tiny generations: a constantly re-read id must stay
        // live while cold ids churn through
        let store = InstanceStore::new(8, 1);
        store.update(42, 9.0, 9.0, 0);
        for id in 1000..1100u64 {
            store.update(id, 0.0, 0.0, 1);
            assert!(store.get(42).is_some(), "hot id evicted at {id}");
        }
    }

    #[test]
    fn snapshot_load_round_trip() {
        let a = InstanceStore::new(256, 4);
        for id in 0..50u64 {
            a.update(id, id as f32, 0.5, 3);
        }
        let snap = a.snapshot();
        assert_eq!(snap.len(), 50);
        let b = InstanceStore::new(256, 8); // different shard count is fine
        b.load(&snap);
        assert_eq!(b.len(), 50);
        for id in 0..50u64 {
            assert_eq!(b.peek(id), a.peek(id), "id {id}");
        }
        assert_eq!(b.snapshot(), snap);
    }

    #[test]
    fn tiny_capacity_rounds_up_to_shard_floor() {
        let store = InstanceStore::new(1, 4);
        assert_eq!(store.capacity(), 8); // 2 gens x 4 shards x 1 record
        for id in 0..100u64 {
            store.update(id, 0.0, 0.0, 0);
        }
        assert!(store.len() <= 8);
    }

    #[test]
    fn merge_is_freshest_tick_wins() {
        let a = InstanceStore::new(256, 4);
        a.update(1, 1.0, 0.1, 5); // resident, fresher
        a.update(2, 2.0, 0.2, 3); // resident, staler
        a.update(3, 3.0, 0.3, 4); // resident, tie
        let incoming = vec![
            (1, InstanceRecord { loss: 9.0, gnorm: 9.0, last_tick: 2, visits: 7 }),
            (2, InstanceRecord { loss: 8.0, gnorm: 8.0, last_tick: 6, visits: 7 }),
            (3, InstanceRecord { loss: 7.0, gnorm: 7.0, last_tick: 4, visits: 7 }),
            (4, InstanceRecord { loss: 6.0, gnorm: 6.0, last_tick: 1, visits: 7 }),
        ];
        a.merge(&incoming);
        assert_eq!(a.peek(1).unwrap().loss, 1.0, "stale gossip overwrote");
        assert_eq!(a.peek(2).unwrap().loss, 8.0, "fresher gossip ignored");
        assert_eq!(a.peek(3).unwrap().loss, 3.0, "tie must keep resident");
        assert_eq!(a.peek(4).unwrap().loss, 6.0, "new id not inserted");
    }

    #[test]
    fn merge_respects_capacity() {
        let a = InstanceStore::new(16, 2);
        let big: Vec<(u64, InstanceRecord)> = (0..1000u64)
            .map(|id| (id, InstanceRecord { loss: 1.0, gnorm: 1.0, last_tick: 9, visits: 1 }))
            .collect();
        a.merge(&big);
        assert!(a.len() <= a.capacity(), "{}/{}", a.len(), a.capacity());
    }

    #[test]
    fn top_by_loss_orders_and_excludes() {
        let s = InstanceStore::new(256, 4);
        for id in 0..10u64 {
            s.update(id, id as f32, 0.0, 1);
        }
        let none = std::collections::HashSet::new();
        let top = s.top_by_loss(3, &none);
        assert_eq!(top.iter().map(|&(id, _)| id).collect::<Vec<_>>(), vec![9, 8, 7]);
        let mut skip = std::collections::HashSet::new();
        skip.insert(9u64);
        skip.insert(7u64);
        let top = s.top_by_loss(3, &skip);
        assert_eq!(top.iter().map(|&(id, _)| id).collect::<Vec<_>>(), vec![8, 6, 5]);
        assert!(s.top_by_loss(100, &none).len() == 10);
    }

    #[test]
    fn dirty_tracking_feeds_delta_gossip() {
        let s = InstanceStore::new(256, 4);
        s.update(1, 1.0, 0.1, 1);
        assert!(s.take_dirty().is_empty(), "tracking must be opt-in");
        s.enable_dirty_tracking();
        s.update(2, 2.0, 0.2, 2);
        s.update(3, 3.0, 0.3, 2);
        s.update(2, 2.5, 0.2, 3); // re-touch: still one entry, latest record
        let d = s.take_dirty();
        assert_eq!(d.iter().map(|&(id, _)| id).collect::<Vec<_>>(), vec![2, 3]);
        assert_eq!(d[0].1.loss, 2.5);
        assert!(s.take_dirty().is_empty(), "take must clear the marks");
        // peer gossip must not re-dirty the receiver (no broadcast echo)
        s.merge(&[(9, InstanceRecord { loss: 1.0, gnorm: 1.0, last_tick: 9, visits: 1 })]);
        assert!(s.take_dirty().is_empty());
        // clear_dirty drops pending marks (a full snapshot just went out)
        s.update(4, 1.0, 0.1, 4);
        s.clear_dirty();
        assert!(s.take_dirty().is_empty());
    }

    #[test]
    fn dirty_ids_evicted_before_sync_are_skipped() {
        let s = InstanceStore::new(8, 1); // tiny store: constant rotation
        s.enable_dirty_tracking();
        for id in 0..100u64 {
            s.update(id, 1.0, 1.0, 1);
        }
        let d = s.take_dirty();
        assert!(d.len() <= s.capacity(), "evicted ids resurfaced: {}", d.len());
        for &(id, _) in &d {
            assert!(s.peek(id).is_some(), "dirty id {id} is not live");
        }
    }

    #[test]
    fn generation_snapshot_restores_exact_eviction_behavior() {
        let a = InstanceStore::new(16, 2); // gen_capacity = 4: constant rotation
        for id in 0..40u64 {
            a.update(id, id as f32, 0.1, id as u32);
        }
        let (snap, old_ids) = a.snapshot_with_generations();
        assert!(!old_ids.is_empty(), "rotation never produced an old generation");
        let b = InstanceStore::new(16, 2);
        assert!(b.load_with_generations(&snap, &old_ids), "same geometry must fit");
        assert_eq!(b.snapshot(), snap);
        let (_, b_old) = b.snapshot_with_generations();
        assert_eq!(b_old, old_ids, "old-generation membership must round-trip");
        // identical continuation: same inserts → same rotations → same content
        for id in 100..140u64 {
            a.update(id, 1.0, 0.2, id as u32);
            b.update(id, 1.0, 0.2, id as u32);
        }
        assert_eq!(a.snapshot(), b.snapshot(), "restored store diverged under pressure");
    }

    #[test]
    fn generation_load_falls_back_on_geometry_mismatch() {
        let a = InstanceStore::new(64, 4);
        for id in 0..200u64 {
            a.update(id, 1.0, 0.1, 1);
        }
        let (snap, old_ids) = a.snapshot_with_generations();
        let b = InstanceStore::new(16, 2); // too small for the saver's split
        assert!(!b.load_with_generations(&snap, &old_ids), "mismatch must fall back");
        assert!(b.len() <= b.capacity());
    }

    #[test]
    fn eviction_sync_mark_tracks_rotations() {
        let s = InstanceStore::new(8, 1);
        assert!(!s.evicted_since_sync(), "fresh store has no evictions");
        s.update(1, 1.0, 1.0, 1);
        assert!(!s.evicted_since_sync(), "inserts without rotation don't trip it");
        for id in 0..100u64 {
            s.update(id, 1.0, 1.0, 1);
        }
        assert!(s.evicted_since_sync());
        s.mark_gossip_synced();
        assert!(!s.evicted_since_sync(), "mark must reset the baseline");
        for id in 100..200u64 {
            s.update(id, 1.0, 1.0, 2);
        }
        assert!(s.evicted_since_sync(), "new rotations re-trip it");
    }

    #[test]
    fn concurrent_updates_stay_bounded() {
        use std::sync::Arc;
        let store = Arc::new(InstanceStore::new(512, 8));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let store = store.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..5_000u64 {
                    store.update(t * 1_000_000 + i, 1.0, 1.0, i as u32);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(store.len() <= store.capacity());
        let c = store.counters();
        assert_eq!(c.evictions + store.len() as u64, 20_000);
    }
}

//! The per-tick training kernel shared by the single-process
//! [`crate::stream::StreamTrainer`] and the multi-node
//! [`crate::cluster`] workers.
//!
//! One [`TickEngine::process`] call handles one micro-batch of arrivals:
//! optional prequential eval, forward + AdaSelection scoring, drift-driven
//! γ / method-weight-rate control, instance-store bookkeeping, replay
//! top-up from the store when arrivals dip below the training budget, and
//! the train step. The engine owns the mutable selection state (policy,
//! store, drift controller, counters); rolling metrics, digest chains and
//! checkpoints stay with the caller.

use std::collections::HashSet;

use crate::metrics::drift::{Adwin, PageHinkley};
use crate::pipeline::{gather, Batch};
use crate::runtime::Backend;
use crate::selection::policy::{Policy, ScoringNeeds, SelectionContext};
use crate::stream::source::StreamSource;
use crate::stream::store::InstanceStore;
use crate::util::json::Json;
use crate::util::timer::PhaseTimer;

pub(crate) const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

pub(crate) fn fnv_fold(mut h: u64, x: u64) -> u64 {
    h ^= x;
    h.wrapping_mul(FNV_PRIME)
}

/// Page–Hinkley defaults tuned for per-tick mean losses in the O(1) range.
const PH_DELTA: f64 = 0.05;
const PH_LAMBDA: f64 = 2.0;

/// ADWIN defaults: cut confidence + window cap (per-tick mean losses).
const ADWIN_DELTA: f64 = 0.005;
const ADWIN_WINDOW: usize = 256;

/// Which change detector drives [`DriftGamma`] (`--drift-detect
/// page-hinkley|adwin`; `off` maps to `None`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DriftKind {
    PageHinkley,
    Adwin,
}

impl DriftKind {
    /// Parse the `--drift-detect` value. `off` (and the legacy booleans
    /// normalized by the config layer) selects no detector.
    pub fn parse(s: &str) -> anyhow::Result<Option<DriftKind>> {
        Ok(match s {
            "off" => None,
            "page-hinkley" => Some(DriftKind::PageHinkley),
            "adwin" => Some(DriftKind::Adwin),
            other => anyhow::bail!(
                "unknown drift detector '{other}' (expected off|page-hinkley|adwin)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            DriftKind::PageHinkley => "page-hinkley",
            DriftKind::Adwin => "adwin",
        }
    }
}

/// The detector behind [`DriftGamma`] — both observe the per-tick mean
/// loss and fire on upward shifts only.
#[derive(Clone, Debug)]
enum Detector {
    Ph(PageHinkley),
    Adwin(Adwin),
}

impl Detector {
    fn new(kind: DriftKind) -> Detector {
        match kind {
            DriftKind::PageHinkley => Detector::Ph(PageHinkley::new(PH_DELTA, PH_LAMBDA)),
            DriftKind::Adwin => Detector::Adwin(Adwin::new(ADWIN_DELTA, ADWIN_WINDOW)),
        }
    }

    fn observe(&mut self, x: f64) -> bool {
        match self {
            Detector::Ph(d) => d.observe(x),
            Detector::Adwin(d) => d.observe(x),
        }
    }

    fn detections(&self) -> u64 {
        match self {
            Detector::Ph(d) => d.detections(),
            Detector::Adwin(d) => d.detections(),
        }
    }

    fn kind(&self) -> DriftKind {
        match self {
            Detector::Ph(_) => DriftKind::PageHinkley,
            Detector::Adwin(_) => DriftKind::Adwin,
        }
    }

    /// Serialized accumulator state (kind + detector fields + detections) —
    /// the same flat pair layout `DriftGamma::to_json` has always written,
    /// reused verbatim for the per-method detector entries.
    fn state_pairs(&self) -> Vec<(&'static str, Json)> {
        let mut pairs = vec![("kind", Json::from(self.kind().name()))];
        match self {
            Detector::Ph(ph) => {
                let (n, mean, cum, min_cum) = ph.state();
                pairs.push(("n", Json::from(n as usize)));
                pairs.push(("mean", Json::from(mean)));
                pairs.push(("cum", Json::from(cum)));
                pairs.push(("min_cum", Json::from(min_cum)));
            }
            Detector::Adwin(a) => {
                pairs.push(("window", Json::arr_f64(&a.window_values())));
            }
        }
        pairs.push(("detections", Json::from(self.detections() as usize)));
        pairs
    }

    /// Restore [`Detector::state_pairs`]; jsons without a `kind` key
    /// predate ADWIN and are Page–Hinkley.
    fn restore_pairs(&mut self, j: &Json) -> anyhow::Result<()> {
        let kind = match j.get("kind") {
            Some(k) => k.as_str()?.to_string(),
            None => "page-hinkley".to_string(),
        };
        anyhow::ensure!(
            kind == self.kind().name(),
            "checkpoint drift detector '{kind}' does not match configured '{}'",
            self.kind().name()
        );
        let detections = j.at(&["detections"])?.as_usize()? as u64;
        match self {
            Detector::Ph(ph) => {
                let n = j.at(&["n"])?.as_usize()? as u64;
                let mean = j.at(&["mean"])?.as_f64()?;
                let cum = j.at(&["cum"])?.as_f64()?;
                let min_cum = j.at(&["min_cum"])?.as_f64()?;
                ph.restore(n, mean, cum, min_cum, detections);
            }
            Detector::Adwin(a) => {
                let vals: Vec<f64> = j
                    .at(&["window"])?
                    .as_arr()?
                    .iter()
                    .map(|v| v.as_f64())
                    .collect::<anyhow::Result<Vec<f64>>>()?;
                a.restore(&vals, detections);
            }
        }
        Ok(())
    }
}

/// Stored-loss decay applied to a replayed instance after its train step.
/// Replay rows skip the forward pass, so their store records would stay
/// frozen at the arrival-time loss and `top_by_loss` would hand back the
/// same ids every lull; decaying the stale loss (a crude proxy for "the
/// step reduced it") rotates the budget through the hard set instead.
const REPLAY_LOSS_DECAY: f32 = 0.7;

/// Drift-adaptive control of γ and the method-weight learning rate
/// (ROADMAP: "real drift detectors driving γ ... instead of fixed"):
/// a change detector ([`PageHinkley`] or [`Adwin`], `--drift-detect`)
/// watches the pre-update mean loss of every tick; a detection boosts the
/// sampling rate (train on more of each chunk) and the weight-update rate
/// (re-rank candidate methods faster) for `hold` ticks, then both fall
/// back to their configured base values.
#[derive(Clone, Debug)]
pub struct DriftGamma {
    det: Detector,
    /// multiplier on γ while a boost is active (capped at γ=1)
    pub gamma_boost: f64,
    /// multiplier on the weight-update rule's learning parameter
    pub lr_boost: f32,
    /// multiplier on a bandit arm's weight when that arm's own detector
    /// fires (per-method drift: shift the method mix, not just γ)
    pub weight_boost: f32,
    /// ticks a boost stays active after a detection
    pub hold: u32,
    left: u32,
    /// one detector per bandit arm (same kind as `det`), each observing
    /// that arm's hypothetical top-k mean loss ℓ_t^m; empty = per-method
    /// drift off (non-AdaSelection policies)
    per_method: Vec<Detector>,
}

impl Default for DriftGamma {
    fn default() -> Self {
        DriftGamma::new(DriftKind::PageHinkley)
    }
}

impl DriftGamma {
    /// A controller driven by the given detector kind.
    pub fn new(kind: DriftKind) -> DriftGamma {
        DriftGamma {
            det: Detector::new(kind),
            gamma_boost: 2.0,
            lr_boost: 3.0,
            weight_boost: 2.0,
            hold: 25,
            left: 0,
            per_method: Vec::new(),
        }
    }

    /// Build the controller a config + policy pair calls for: `None` when
    /// `--drift-detect off` or the policy runs no selection forward pass
    /// (nothing to observe); per-method detectors attached for
    /// AdaSelection pools, one per bandit arm.
    pub fn from_config(
        cfg: &crate::config::StreamConfig,
        policy: &Policy,
    ) -> anyhow::Result<Option<DriftGamma>> {
        let kind = match DriftKind::parse(&cfg.drift_detect)? {
            Some(k) => k,
            None => return Ok(None),
        };
        if policy.scoring() == ScoringNeeds::None {
            return Ok(None);
        }
        let mut d = DriftGamma::new(kind);
        if let Some(ada) = policy.as_ada_ref() {
            d.enable_per_method(ada.state().config().candidates.len());
        }
        Ok(Some(d))
    }

    /// The detector behind this controller.
    pub fn kind(&self) -> DriftKind {
        self.det.kind()
    }

    /// Attach one fresh detector (same kind) per bandit arm.
    pub fn enable_per_method(&mut self, arms: usize) {
        self.per_method = (0..arms).map(|_| Detector::new(self.det.kind())).collect();
    }

    /// Number of per-method detectors attached (0 = per-method drift off).
    pub fn per_method_arms(&self) -> usize {
        self.per_method.len()
    }

    /// Feed every arm's observed loss ℓ_t^m for this tick; returns the
    /// arm indices whose detectors fired.
    pub fn observe_methods(&mut self, losses: &[f32]) -> Vec<usize> {
        let mut fired = Vec::new();
        for (i, det) in self.per_method.iter_mut().enumerate() {
            if i >= losses.len() {
                break;
            }
            if det.observe(losses[i] as f64) {
                fired.push(i);
            }
        }
        fired
    }

    /// Total detections across the per-method detectors.
    pub fn method_detections(&self) -> u64 {
        self.per_method.iter().map(|d| d.detections()).sum()
    }

    /// Feed one tick's mean loss; `true` on a fresh detection.
    pub fn observe(&mut self, mean_loss: f64) -> bool {
        if self.det.observe(mean_loss) {
            self.left = self.hold;
            true
        } else {
            self.left = self.left.saturating_sub(1);
            false
        }
    }

    pub fn boost_active(&self) -> bool {
        self.left > 0
    }

    pub fn gamma_factor(&self) -> f64 {
        if self.left > 0 {
            self.gamma_boost
        } else {
            1.0
        }
    }

    pub fn lr_scale(&self) -> f32 {
        if self.left > 0 {
            self.lr_boost
        } else {
            1.0
        }
    }

    pub fn detections(&self) -> u64 {
        self.det.detections()
    }

    /// Checkpoint payload (deterministic resume needs the detector
    /// accumulators, the remaining boost window, and every per-method
    /// detector). The base detector's fields stay flat at the top level —
    /// the pre-v3 layout — so older checkpoints round-trip unchanged;
    /// per-method detectors ride in a `per_method` array of the same
    /// per-detector layout.
    pub fn to_json(&self) -> Json {
        let mut pairs = self.det.state_pairs();
        pairs.push(("left", Json::from(self.left as usize)));
        if !self.per_method.is_empty() {
            pairs.push((
                "per_method",
                Json::Arr(
                    self.per_method
                        .iter()
                        .map(|d| Json::obj(d.state_pairs()))
                        .collect(),
                ),
            ));
        }
        Json::obj(pairs)
    }

    /// Restore [`DriftGamma::to_json`] state. The checkpointed detector
    /// kind must match this controller's (resume identity pins the
    /// `--drift-detect` value). A checkpoint without a `per_method` key
    /// predates per-method drift: attached detectors simply start fresh.
    pub fn restore_json(&mut self, j: &Json) -> anyhow::Result<()> {
        self.det.restore_pairs(j)?;
        self.left = j.at(&["left"])?.as_usize()? as u32;
        if let Some(arr) = j.get("per_method") {
            let arr = arr.as_arr()?;
            anyhow::ensure!(
                arr.len() == self.per_method.len(),
                "checkpoint has {} per-method detectors, policy has {} arms",
                arr.len(),
                self.per_method.len()
            );
            for (det, dj) in self.per_method.iter_mut().zip(arr.iter()) {
                det.restore_pairs(dj)?;
            }
        }
        Ok(())
    }
}

/// Everything one tick produced (the caller folds this into its rolling
/// metrics / digest chain).
#[derive(Clone, Debug)]
pub struct TickOutcome {
    /// real arrivals in this tick's chunk
    pub arrivals: usize,
    /// rows trained on (selected arrivals + replayed store rows)
    pub trained: usize,
    /// rows of `trained` that came from the replay scheduler
    pub replayed: usize,
    /// (loss_sum, correct_sum) over the arrivals, when prequential eval ran
    pub eval: Option<(f32, f32)>,
    /// FNV digest over the trained ids (selected order, then replay order)
    pub digest: u64,
}

/// The reusable per-tick trainer core. `chunk_rows` is the stream's chunk
/// width (the family batch size) — the id inversion the replay fetch needs.
/// Cumulative engine counters sampled for telemetry (see
/// [`TickEngine::telemetry`]); also the payload the cluster `Heartbeat`
/// wire message piggybacks.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EngineTelemetry {
    pub samples_seen: u64,
    pub samples_trained: u64,
    pub samples_replayed: u64,
    pub samples_forward: u64,
    pub drift_detections: u64,
    pub store_len: u64,
}

pub struct TickEngine {
    pub policy: Policy,
    pub store: InstanceStore,
    pub gamma: f64,
    pub lr: f32,
    chunk_rows: usize,
    /// per-tick training budget in rows; arrivals below it are topped up
    /// with high-loss store rows (None = replay off)
    pub replay_budget: Option<usize>,
    pub drift: Option<DriftGamma>,
    pub samples_seen: u64,
    pub samples_trained: u64,
    pub samples_replayed: u64,
    /// rows put through the selection forward pass (candidate scoring);
    /// benchmark runs keep this at 0, OBFTF at ≈ obftf_k·⌈γB⌉ per tick
    pub samples_forward: u64,
}

impl TickEngine {
    pub fn new(
        policy: Policy,
        store: InstanceStore,
        gamma: f64,
        lr: f32,
        chunk_rows: usize,
    ) -> TickEngine {
        TickEngine {
            policy,
            store,
            gamma,
            lr,
            chunk_rows: chunk_rows.max(1),
            replay_budget: None,
            drift: None,
            samples_seen: 0,
            samples_trained: 0,
            samples_replayed: 0,
            samples_forward: 0,
        }
    }

    /// This tick's effective sampling rate (base γ times any drift boost).
    pub fn effective_gamma(&self) -> f64 {
        match &self.drift {
            Some(d) => (self.gamma * d.gamma_factor()).min(1.0),
            None => self.gamma,
        }
    }

    pub fn drift_detections(&self) -> u64 {
        self.drift.as_ref().map(|d| d.detections()).unwrap_or(0)
    }

    /// Point-in-time telemetry snapshot of the engine's cumulative
    /// counters plus current store occupancy — what heartbeats piggyback
    /// and the [`crate::obs::TickObserver`] samples. Read-only: taking a
    /// snapshot cannot perturb selection.
    pub fn telemetry(&self) -> EngineTelemetry {
        EngineTelemetry {
            samples_seen: self.samples_seen,
            samples_trained: self.samples_trained,
            samples_replayed: self.samples_replayed,
            samples_forward: self.samples_forward,
            drift_detections: self.drift_detections(),
            store_len: self.store.len() as u64,
        }
    }

    /// Run one tick: prequential eval (optional), score + select + store,
    /// replay top-up, train step.
    #[allow(clippy::too_many_arguments)]
    pub fn process<B: Backend>(
        &mut self,
        backend: &mut B,
        state: &mut B::State,
        source: &dyn StreamSource,
        batch: &Batch,
        tick: u64,
        do_eval: bool,
        phases: &mut PhaseTimer,
    ) -> anyhow::Result<TickOutcome> {
        let real = batch.real;
        self.samples_seen += real as u64;

        // prequential test-then-train: score the arrivals before any of
        // them is trained on
        let mut eval_out = None;
        if do_eval && real > 0 {
            let r = phases.time("eval", || backend.eval(state, batch))?;
            eval_out = Some(r);
        }

        let mut selected: Vec<usize> = Vec::new();
        let mut digest = FNV_OFFSET;
        if real > 0 {
            if self.policy.scoring() == ScoringNeeds::None {
                // no selection forward pass at all: train on everything
                selected = (0..real).collect();
            } else {
                // phase 1: the policy plans which rows need forward-only
                // scoring (OBFTF plans a candidate superset; everyone else
                // scores the full batch). Planned with base γ — the drift
                // boost below only widens the final keep count.
                let k_base = ((self.gamma * real as f64).ceil() as usize).clamp(1, real);
                let cand_rows = self.policy.plan(real, k_base).candidate_rows;

                // phase 2 scoring: candidate-subset forward when planned;
                // otherwise the full batch — fused on the backend scorer
                // when AdaSelection's pool is all-kernel, separate passes
                // else. Full-batch α/scores are computed over the padded
                // batch (compiled-shape friendly) and sliced to the real
                // arrivals before selection.
                let (loss_c, gnorm_c, prepared) = match &cand_rows {
                    Some(rows) => {
                        let (l, g) = phases.time("forward", || {
                            crate::runtime::forward_scores_rows(backend, state, batch, rows)
                        })?;
                        (l, g, None)
                    }
                    None => {
                        let fused = match self.policy.as_ada() {
                            Some(ada) => match ada.state().kernel_weights() {
                                Some(w_full) => {
                                    let t_next = ada.state().iteration() + 1;
                                    let (cl_on, cl_power) = {
                                        let c = ada.state().config();
                                        (c.cl_on, c.cl_power)
                                    };
                                    phases.time("forward", || {
                                        backend.forward_score_fused(
                                            state, batch, &w_full, t_next, cl_power, cl_on,
                                        )
                                    })?
                                }
                                None => None,
                            },
                            None => None,
                        };
                        match fused {
                            Some(f) => {
                                let loss_real = f.loss[..real].to_vec();
                                let gnorm_real = f.gnorm[..real].to_vec();
                                let scores = f.scores[..real].to_vec();
                                let alphas: Vec<Vec<f32>> =
                                    f.alphas.iter().map(|row| row[..real].to_vec()).collect();
                                (loss_real, gnorm_real, Some((scores, alphas)))
                            }
                            None => {
                                let (loss, gnorm) = phases
                                    .time("forward", || backend.forward_scores(state, batch))?;
                                (loss[..real].to_vec(), gnorm[..real].to_vec(), None)
                            }
                        }
                    }
                };
                let n_cand = loss_c.len();
                self.samples_forward += n_cand as u64;

                // drift control: the tick that exposes a loss jump already
                // trains harder — observe the scored rows' mean loss, then
                // derive γ and the weight-update rate for this very tick
                if let Some(d) = self.drift.as_mut() {
                    let mean =
                        loss_c.iter().map(|&l| l as f64).sum::<f64>() / n_cand.max(1) as f64;
                    d.observe(mean);
                }
                let gamma_eff = self.effective_gamma();
                let k = ((gamma_eff * real as f64).ceil() as usize).clamp(1, n_cand);
                let lr_scale =
                    self.drift.as_ref().map(|d| d.lr_scale()).unwrap_or(1.0);
                if let Some(ada) = self.policy.as_ada() {
                    ada.state_mut().set_lr_scale(lr_scale);
                }

                let t0 = std::time::Instant::now();
                let picks = match prepared {
                    Some((scores, alphas)) => {
                        let ada = self.policy.as_ada().expect("fused path is ada-only");
                        ada.select_kernel(&loss_c, &alphas, scores, k)
                    }
                    None => self.policy.select(&SelectionContext {
                        loss: &loss_c,
                        gnorm: &gnorm_c,
                        k,
                        history: Some(&self.store),
                    }),
                };
                // map candidate-local picks back to batch positions
                selected = match &cand_rows {
                    Some(rows) => picks.iter().map(|&c| rows[c]).collect(),
                    None => picks,
                };
                phases.add("select", t0.elapsed());

                // per-method drift: each bandit arm's detector watches that
                // arm's own ℓ_t^m; a firing arm gets its weight boosted so
                // a regime change re-ranks the method mix, not just γ
                if let (Some(d), Some(ada)) = (self.drift.as_mut(), self.policy.as_ada()) {
                    if d.per_method_arms() > 0 {
                        if let Some(cur) = ada.state().last_method_losses() {
                            let cur = cur.to_vec();
                            let boost = d.weight_boost;
                            for m in d.observe_methods(&cur) {
                                ada.state_mut().boost_weight(m, boost);
                            }
                        }
                    }
                }

                // constant information per instance: record every scored row
                let t0 = std::time::Instant::now();
                let tick32 = tick.min(u32::MAX as u64) as u32;
                match &cand_rows {
                    Some(rows) => {
                        for ((&row, &l), &g) in
                            rows.iter().zip(loss_c.iter()).zip(gnorm_c.iter())
                        {
                            self.store.update(batch.indices[row] as u64, l, g, tick32);
                        }
                    }
                    None => {
                        for ((&id, &l), &g) in batch.indices[..real]
                            .iter()
                            .zip(loss_c.iter())
                            .zip(gnorm_c.iter())
                        {
                            self.store.update(id as u64, l, g, tick32);
                        }
                    }
                }
                phases.add("store", t0.elapsed());
            }
        }

        // replay top-up: when the tick's arrivals leave the training
        // budget underfilled (burst lull or a thin cluster shard), spend
        // the idle cycles revisiting the highest-loss stored instances
        let mut replay_ids: Vec<u64> = Vec::new();
        let mut replay_batch: Option<Batch> = None;
        if let Some(budget) = self.replay_budget {
            let deficit = budget.saturating_sub(selected.len());
            if deficit > 0 && !self.store.is_empty() {
                let t0 = std::time::Instant::now();
                let exclude: HashSet<u64> =
                    batch.indices[..real].iter().map(|&i| i as u64).collect();
                let picks = self.store.top_by_loss(deficit, &exclude);
                if !picks.is_empty() {
                    let ids: Vec<u64> = picks.iter().map(|&(id, _)| id).collect();
                    let chunk = source.fetch(&ids, self.chunk_rows);
                    if !chunk.ids.is_empty() {
                        let rows: Vec<usize> = (0..chunk.data.len()).collect();
                        let mut rb = gather(&chunk.data, &rows, rows.len(), 0, tick as usize);
                        rb.indices = chunk.ids.iter().map(|&g| g as usize).collect();
                        replay_ids = chunk.ids;
                        replay_batch = Some(rb);
                    }
                }
                phases.add("replay", t0.elapsed());
            }
        }

        let trained = selected.len() + replay_ids.len();
        if trained > 0 {
            let sub = match replay_batch {
                Some(rb) if selected.is_empty() => rb,
                Some(rb) => batch.gather_rows(&selected).concat(&rb),
                None => batch.gather_rows(&selected),
            };
            phases.time("update", || backend.train_step(state, &sub, self.lr))?;
            self.samples_trained += trained as u64;
            self.samples_replayed += replay_ids.len() as u64;
            for &row in &selected {
                digest = fnv_fold(digest, batch.indices[row] as u64);
            }
            let tick32 = tick.min(u32::MAX as u64) as u32;
            for &id in &replay_ids {
                digest = fnv_fold(digest, id);
                // mark the revisit: decay the stale loss so the next lull
                // picks the next-hardest ids, and bump visits/last_tick
                if let Some(rec) = self.store.peek(id) {
                    self.store
                        .update(id, rec.loss * REPLAY_LOSS_DECAY, rec.gnorm, tick32);
                }
            }
        }

        Ok(TickOutcome {
            arrivals: real,
            trained,
            replayed: replay_ids.len(),
            eval: eval_out,
            digest,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_fold_distinguishes_sequences() {
        let a = [1u64, 2, 3].iter().fold(FNV_OFFSET, |h, &x| fnv_fold(h, x));
        let b = [3u64, 2, 1].iter().fold(FNV_OFFSET, |h, &x| fnv_fold(h, x));
        assert_ne!(a, b);
    }

    #[test]
    fn drift_gamma_boosts_then_decays() {
        let mut d = DriftGamma { hold: 3, ..DriftGamma::default() };
        assert!(!d.boost_active());
        assert_eq!(d.gamma_factor(), 1.0);
        assert_eq!(d.lr_scale(), 1.0);
        // stationary, then a large step: PH fires within a few ticks
        let mut fired = false;
        for _ in 0..50 {
            fired |= d.observe(1.0);
        }
        assert!(!fired, "false positive on stationary signal");
        for _ in 0..20 {
            if d.observe(3.0) {
                fired = true;
                break;
            }
        }
        assert!(fired, "no detection on a 3x loss step");
        assert!(d.boost_active());
        assert!(d.gamma_factor() > 1.0 && d.lr_scale() > 1.0);
        assert_eq!(d.detections(), 1);
        // hold window decays back to base
        for _ in 0..3 {
            d.observe(1.0);
        }
        assert!(!d.boost_active());
        assert_eq!(d.gamma_factor(), 1.0);
    }

    #[test]
    fn drift_gamma_state_round_trips() {
        let mut a = DriftGamma::default();
        for i in 0..30 {
            a.observe(1.0 + (i % 5) as f64 * 0.01);
        }
        let j = a.to_json();
        let mut b = DriftGamma::default();
        b.restore_json(&j).unwrap();
        for x in [1.0, 1.5, 2.5, 4.0, 4.0, 4.0] {
            assert_eq!(a.observe(x), b.observe(x));
            assert_eq!(a.boost_active(), b.boost_active());
        }
        assert_eq!(a.detections(), b.detections());
        // garbage json rejected
        assert!(DriftGamma::default().restore_json(&Json::Null).is_err());
    }

    #[test]
    fn adwin_drift_gamma_boosts_and_round_trips() {
        let mut d = DriftGamma::new(DriftKind::Adwin);
        assert_eq!(d.kind(), DriftKind::Adwin);
        let mut fired = false;
        for _ in 0..50 {
            fired |= d.observe(1.0);
        }
        assert!(!fired, "false positive on stationary signal");
        for _ in 0..30 {
            if d.observe(3.0) {
                fired = true;
                break;
            }
        }
        assert!(fired, "no ADWIN detection on a 3x loss step");
        assert!(d.boost_active());
        // checkpoint round-trip keeps the window in sync
        let j = d.to_json();
        let mut b = DriftGamma::new(DriftKind::Adwin);
        b.restore_json(&j).unwrap();
        for x in [3.0, 3.1, 2.9, 3.0, 6.5, 6.5, 6.5, 6.5, 6.5, 6.5, 6.5, 6.5] {
            assert_eq!(d.observe(x), b.observe(x));
        }
        assert_eq!(d.detections(), b.detections());
        // a Page–Hinkley checkpoint cannot restore into an ADWIN controller
        let ph_json = DriftGamma::default().to_json();
        assert!(DriftGamma::new(DriftKind::Adwin).restore_json(&ph_json).is_err());
        // and the selector grammar is pinned
        assert_eq!(DriftKind::parse("off").unwrap(), None);
        assert_eq!(DriftKind::parse("adwin").unwrap(), Some(DriftKind::Adwin));
        assert_eq!(
            DriftKind::parse("page-hinkley").unwrap(),
            Some(DriftKind::PageHinkley)
        );
        assert!(DriftKind::parse("bogus").is_err());
    }

    #[test]
    fn per_method_detectors_fire_independently_and_round_trip() {
        let mut d = DriftGamma::default();
        d.enable_per_method(2);
        assert_eq!(d.per_method_arms(), 2);
        for _ in 0..50 {
            assert!(d.observe_methods(&[1.0, 1.0]).is_empty());
        }
        // only arm 1 sees a shift: only its detector may fire
        let mut hit = None;
        for _ in 0..30 {
            let f = d.observe_methods(&[1.0, 4.0]);
            if !f.is_empty() {
                hit = Some(f);
                break;
            }
        }
        assert_eq!(hit, Some(vec![1]));
        assert!(d.method_detections() >= 1);
        // per-method state rides the json round trip tick-for-tick
        let j = d.to_json();
        let mut b = DriftGamma::default();
        b.enable_per_method(2);
        b.restore_json(&j).unwrap();
        assert_eq!(b.method_detections(), d.method_detections());
        for _ in 0..10 {
            assert_eq!(
                d.observe_methods(&[1.0, 4.0]),
                b.observe_methods(&[1.0, 4.0])
            );
        }
        // arity mismatch rejected
        let mut c = DriftGamma::default();
        c.enable_per_method(3);
        assert!(c.restore_json(&j).is_err());
        // kind mismatch rejected (adwin controller, page-hinkley payload)
        let mut k = DriftGamma::new(DriftKind::Adwin);
        k.enable_per_method(2);
        assert!(k.restore_json(&j).is_err());
        // a pre-per-method payload restores with fresh arm detectors
        let legacy = DriftGamma::default().to_json();
        let mut fresh = DriftGamma::default();
        fresh.enable_per_method(2);
        fresh.restore_json(&legacy).unwrap();
        assert_eq!(fresh.method_detections(), 0);
    }

    #[test]
    fn effective_gamma_is_capped() {
        let store = InstanceStore::new(64, 2);
        let policy = crate::selection::policy::build_policy("uniform", 0, 0.5, true, -0.5).unwrap();
        let mut e = TickEngine::new(policy, store, 0.8, 0.01, 16);
        let mut d = DriftGamma::default();
        d.left = 5;
        e.drift = Some(d);
        assert_eq!(e.effective_gamma(), 1.0); // 0.8 * 2.0 capped
        e.drift = None;
        assert_eq!(e.effective_gamma(), 0.8);
    }
}

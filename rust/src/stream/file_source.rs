//! [`FileTailSource`]: a [`StreamSource`] over a line-delimited log file —
//! the first real-feed source behind the same trait the synthetic
//! generators implement (ROADMAP: "stream sources backed by real feeds").
//!
//! ## Format
//!
//! One header line, then one line per sample:
//!
//! ```text
//! #stream-log v1 family=stream_class task=class classes=10 feat=32
//! <tick> <id> <x1,...,xD> <y>
//! ```
//!
//! `task=class` carries `classes=N feat=D` with one i32 label;
//! `task=reg` carries `feat=D` with one f32 target; `task=lm` carries
//! `vocab=V seq=S` with S comma-joined tokens on both x and y.
//!
//! ## Watermarking
//!
//! Producers append roughly in tick order but real feeds deliver *late*
//! records. Lines are scanned in file order with a watermark = the highest
//! event tick seen so far; a line whose event tick is more than
//! `lateness` ticks behind the watermark is reassigned to the watermark
//! tick (it trains as a fresh arrival — dropping it would waste the
//! sample) and counted in [`FileTailSource::late_count`]. Buckets are
//! then capped at the log's natural chunk width (the widest on-time
//! tick), with overflow spilling into the following ticks so reassigned
//! records never exceed what a `gen_chunk(tick, B)` caller will consume.
//! All of this happens once at load, so `gen_chunk` stays pure in the
//! tick and the loader's out-of-order workers stay deterministic.

use std::collections::{BTreeMap, HashMap};
use std::path::Path;

use crate::data::{Dataset, Task, XStore, YStore};
use crate::stream::source::{StreamChunk, StreamSource};

/// Default allowed lateness (ticks) for the `file:PATH` spec.
pub const DEFAULT_LATENESS: u64 = 2;

/// Known model families a log header may name (the native backend table).
fn static_family(name: &str) -> anyhow::Result<&'static str> {
    Ok(match name {
        "stream_class" => "stream_class",
        "mlp_simple" => "mlp_simple",
        "mlp_bike" => "mlp_bike",
        "resnet_c10" => "resnet_c10",
        "resnet_c100" => "resnet_c100",
        "transformer" => "transformer",
        other => anyhow::bail!("stream-log header names unknown family '{other}'"),
    })
}

/// Parsed `key=value` header fields.
struct Header {
    family: &'static str,
    task: Task,
    feat: usize,
}

fn parse_header(line: &str) -> anyhow::Result<Header> {
    anyhow::ensure!(
        line.starts_with("#stream-log v1"),
        "not a stream log (expected '#stream-log v1' header, got {line:?})"
    );
    let mut kv: HashMap<&str, &str> = HashMap::new();
    for tok in line.split_whitespace().skip(2) {
        let (k, v) = tok
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("bad header token '{tok}'"))?;
        kv.insert(k, v);
    }
    let get = |k: &str| -> anyhow::Result<&str> {
        kv.get(k)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("stream-log header missing '{k}'"))
    };
    let family = static_family(get("family")?)?;
    let (task, feat) = match get("task")? {
        "class" => {
            let classes: usize = get("classes")?.parse()?;
            let feat: usize = get("feat")?.parse()?;
            (Task::Classification { classes }, feat)
        }
        "reg" => {
            let feat: usize = get("feat")?.parse()?;
            (Task::Regression, feat)
        }
        "lm" => {
            let vocab: usize = get("vocab")?.parse()?;
            let seq: usize = get("seq")?.parse()?;
            (Task::Lm { vocab, seq }, seq)
        }
        other => anyhow::bail!("stream-log header has unknown task '{other}'"),
    };
    Ok(Header { family, task, feat })
}

/// One parsed record before bucket freezing:
/// `(id, x_f32, x_i32, y_f32, y_i32, y_seq)` — exactly one x and one y
/// side is populated, per the header's task.
type RawRec = (u64, Vec<f32>, Vec<i32>, f32, i32, Vec<i32>);

/// A tick bucket: sample ids plus their dense rows.
struct Bucket {
    ids: Vec<u64>,
    data: Dataset,
}

/// File-backed stream source with late-arrival watermarking. Also the
/// parsing/bucketing core behind the socket tail
/// (`stream::socket_source`), which ingests the identical `#stream-log
/// v1` line format from a TCP feed via [`FileTailSource::from_text`].
pub struct FileTailSource {
    /// registry name: "file" when opened from a path, "tcp" when the
    /// socket tail ingested the feed
    name: &'static str,
    family: &'static str,
    task: Task,
    /// per-effective-tick buckets (load-time watermark assignment)
    buckets: BTreeMap<u64, Bucket>,
    /// id → (effective tick, row) for O(1) replay fetch
    index: HashMap<u64, (u64, usize)>,
    /// zero-row dataset template for empty ticks
    template: Dataset,
    late: u64,
}

impl FileTailSource {
    /// Load a stream log, reassigning records later than `lateness` ticks
    /// behind the watermark.
    pub fn open(path: &Path, lateness: u64) -> anyhow::Result<FileTailSource> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read stream log {path:?}: {e}"))?;
        Self::from_text(&text, lateness, "file")
            .map_err(|e| anyhow::anyhow!("stream log {path:?}: {e}"))
    }

    /// Parse a complete `#stream-log v1` document (the shared core of the
    /// file and socket tails).
    pub fn from_text(
        text: &str,
        lateness: u64,
        name: &'static str,
    ) -> anyhow::Result<FileTailSource> {
        let mut lines = text.lines();
        let header = parse_header(
            lines.next().ok_or_else(|| anyhow::anyhow!("empty stream log"))?,
        )?;

        let template = empty_dataset(&header);
        let mut raw: BTreeMap<u64, Vec<RawRec>> = BTreeMap::new();
        // per-event-tick counts of on-time lines: their maximum is the
        // log's natural chunk width, the spill cap below
        let mut on_time_counts: HashMap<u64, usize> = HashMap::new();
        let mut seen_ids: std::collections::HashSet<u64> = std::collections::HashSet::new();
        let mut watermark = 0u64;
        let mut late = 0u64;
        for (lineno, line) in lines.enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let toks: Vec<&str> = line.split_whitespace().collect();
            anyhow::ensure!(
                toks.len() == 4,
                "line {}: expected '<tick> <id> <x_csv> <y>' (4 fields), got {}",
                lineno + 2,
                toks.len()
            );
            let event_tick: u64 = toks[0].parse()?;
            let id: u64 = toks[1].parse()?;
            anyhow::ensure!(
                seen_ids.insert(id),
                "line {}: duplicate sample id {id}",
                lineno + 2
            );
            let x_str = toks[2];
            let y_str = toks[3];

            let effective = if event_tick + lateness < watermark {
                late += 1;
                watermark
            } else {
                *on_time_counts.entry(event_tick).or_insert(0) += 1;
                event_tick
            };
            watermark = watermark.max(event_tick);

            let mut xf: Vec<f32> = Vec::new();
            let mut xi: Vec<i32> = Vec::new();
            let mut yf = 0.0f32;
            let mut yi = 0i32;
            let mut yseq: Vec<i32> = Vec::new();
            match &header.task {
                Task::Classification { classes } => {
                    xf = parse_csv_f32(x_str, header.feat, lineno)?;
                    yi = y_str.parse()?;
                    anyhow::ensure!(
                        yi >= 0 && (yi as usize) < *classes,
                        "line {}: label {yi} out of range",
                        lineno + 2
                    );
                }
                Task::Regression => {
                    xf = parse_csv_f32(x_str, header.feat, lineno)?;
                    yf = y_str.parse()?;
                    anyhow::ensure!(
                        yf.is_finite(),
                        "line {}: non-finite regression target",
                        lineno + 2
                    );
                }
                Task::Lm { seq, .. } => {
                    xi = parse_csv_i32(x_str, *seq, lineno)?;
                    yseq = parse_csv_i32(y_str, *seq, lineno)?;
                }
            }
            raw.entry(effective).or_default().push((id, xf, xi, yf, yi, yseq));
        }

        // Spill pass: watermark reassignment can pile late records onto an
        // already-full tick; rather than letting `gen_chunk` silently drop
        // the overflow, cap every bucket at the log's natural chunk width
        // (the widest on-time tick) and flow the excess into the following
        // ticks — late arrivals train a little later, never vanish.
        let cap = on_time_counts.values().copied().max().unwrap_or(1).max(1);
        let mut capped: BTreeMap<u64, Vec<RawRec>> = BTreeMap::new();
        let mut carry: Vec<RawRec> = Vec::new();
        let mut cursor = 0u64;
        for (tick, rows) in raw {
            while !carry.is_empty() && cursor < tick {
                let take = carry.len().min(cap);
                capped.insert(cursor, carry.drain(..take).collect());
                cursor += 1;
            }
            let mut bucket: Vec<RawRec> = std::mem::take(&mut carry);
            bucket.extend(rows);
            if bucket.len() > cap {
                carry.extend(bucket.drain(cap..));
            }
            capped.insert(tick, bucket);
            cursor = tick + 1;
        }
        while !carry.is_empty() {
            let take = carry.len().min(cap);
            capped.insert(cursor, carry.drain(..take).collect());
            cursor += 1;
        }

        // freeze buckets into dense datasets
        let mut buckets: BTreeMap<u64, Bucket> = BTreeMap::new();
        let mut index: HashMap<u64, (u64, usize)> = HashMap::new();
        for (tick, rows) in capped {
            let mut ids = Vec::with_capacity(rows.len());
            let mut data = template.clone();
            for (row_i, (id, xf, xi, yf, yi, yseq)) in rows.into_iter().enumerate() {
                ids.push(id);
                index.insert(id, (tick, row_i));
                match &mut data.x {
                    XStore::F32 { data, .. } => data.extend_from_slice(&xf),
                    XStore::I32 { data, .. } => data.extend_from_slice(&xi),
                }
                match &mut data.y {
                    YStore::F32(v) => v.push(yf),
                    YStore::I32(v) => v.push(yi),
                    YStore::Seq { data, .. } => data.extend_from_slice(&yseq),
                }
            }
            data.validate()?;
            buckets.insert(tick, Bucket { ids, data });
        }

        Ok(FileTailSource {
            name,
            family: header.family,
            task: header.task,
            buckets,
            index,
            template,
            late,
        })
    }

    /// Records reassigned to the watermark tick because they arrived more
    /// than `lateness` ticks late.
    pub fn late_count(&self) -> u64 {
        self.late
    }

    /// Highest effective tick with at least one record.
    pub fn max_tick(&self) -> u64 {
        self.buckets.keys().next_back().copied().unwrap_or(0)
    }

    /// Total records loaded.
    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }
}

fn empty_dataset(h: &Header) -> Dataset {
    let (x, y, feat_shape) = match &h.task {
        Task::Classification { .. } => (
            XStore::F32 { data: Vec::new(), stride: h.feat },
            YStore::I32(Vec::new()),
            vec![h.feat],
        ),
        Task::Regression => (
            XStore::F32 { data: Vec::new(), stride: h.feat },
            YStore::F32(Vec::new()),
            vec![h.feat],
        ),
        Task::Lm { seq, .. } => (
            XStore::I32 { data: Vec::new(), stride: *seq },
            YStore::Seq { data: Vec::new(), stride: *seq },
            vec![*seq],
        ),
    };
    Dataset {
        name: "stream-log".into(),
        task: h.task.clone(),
        feat_shape,
        x,
        y,
    }
}

fn parse_csv_f32(s: &str, want: usize, lineno: usize) -> anyhow::Result<Vec<f32>> {
    let v: Vec<f32> = s
        .split(',')
        .map(|t| t.parse::<f32>().map_err(Into::into))
        .collect::<anyhow::Result<Vec<f32>>>()?;
    anyhow::ensure!(
        v.len() == want,
        "line {}: expected {want} features, got {}",
        lineno + 2,
        v.len()
    );
    anyhow::ensure!(
        v.iter().all(|x| x.is_finite()),
        "line {}: non-finite feature value",
        lineno + 2
    );
    Ok(v)
}

fn parse_csv_i32(s: &str, want: usize, lineno: usize) -> anyhow::Result<Vec<i32>> {
    let v: Vec<i32> = s
        .split(',')
        .map(|t| t.parse::<i32>().map_err(Into::into))
        .collect::<anyhow::Result<Vec<i32>>>()?;
    anyhow::ensure!(
        v.len() == want,
        "line {}: expected {want} tokens, got {}",
        lineno + 2,
        v.len()
    );
    Ok(v)
}

impl StreamSource for FileTailSource {
    fn name(&self) -> &'static str {
        self.name
    }

    fn family(&self) -> &'static str {
        self.family
    }

    fn task(&self) -> Task {
        self.task.clone()
    }

    /// Buckets are pre-capped at the log's natural chunk width, so no rows
    /// are lost when callers use the family batch size; asking for fewer
    /// (`max_rows` below the cap) narrows the chunk explicitly.
    fn gen_chunk(&self, tick: u64, max_rows: usize) -> StreamChunk {
        match self.buckets.get(&tick) {
            Some(b) => {
                let n = b.ids.len().min(max_rows);
                if n == b.ids.len() {
                    StreamChunk { ids: b.ids.clone(), data: b.data.clone() }
                } else {
                    let rows: Vec<usize> = (0..n).collect();
                    StreamChunk {
                        ids: b.ids[..n].to_vec(),
                        data: b.data.select_rows(&rows),
                    }
                }
            }
            None => StreamChunk {
                ids: Vec::new(),
                data: self.template.clone(),
            },
        }
    }

    /// Direct id lookup instead of tick regeneration (file ids need not
    /// encode their tick).
    fn fetch(&self, ids: &[u64], _max_rows: usize) -> StreamChunk {
        let mut found: Vec<(u64, usize, u64)> = Vec::new(); // (tick, row, id)
        for &id in ids {
            if let Some(&(tick, row)) = self.index.get(&id) {
                found.push((tick, row, id));
            }
        }
        found.sort_unstable();
        found.dedup();
        let mut out_ids = Vec::with_capacity(found.len());
        let mut data = self.template.clone();
        for (tick, row, id) in found {
            let b = &self.buckets[&tick];
            data.append(&b.data.select_rows(&[row]));
            out_ids.push(id);
        }
        StreamChunk { ids: out_ids, data }
    }
}

/// Write `ticks` chunks of `source` (width `max_rows`) as a stream log —
/// the producer side of the format, used by tests and by operators
/// capturing synthetic traffic for replay through the file path.
pub fn write_stream_log(
    path: &Path,
    source: &dyn StreamSource,
    ticks: u64,
    max_rows: usize,
) -> anyhow::Result<()> {
    std::fs::write(path, stream_log_text(source, ticks, max_rows)?)?;
    Ok(())
}

/// Render `ticks` chunks of `source` as the `#stream-log v1` document —
/// what [`write_stream_log`] persists and what a socket producer streams
/// over TCP (`stream::socket_source` tests drive exactly this).
pub fn stream_log_text(
    source: &dyn StreamSource,
    ticks: u64,
    max_rows: usize,
) -> anyhow::Result<String> {
    use std::fmt::Write as _;
    let mut out = String::new();
    match source.task() {
        Task::Classification { classes } => {
            let feat = source.gen_chunk(0, 1).data.x.stride();
            writeln!(
                out,
                "#stream-log v1 family={} task=class classes={classes} feat={feat}",
                source.family()
            )?;
        }
        Task::Regression => {
            let feat = source.gen_chunk(0, 1).data.x.stride();
            writeln!(
                out,
                "#stream-log v1 family={} task=reg feat={feat}",
                source.family()
            )?;
        }
        Task::Lm { vocab, seq } => {
            writeln!(
                out,
                "#stream-log v1 family={} task=lm vocab={vocab} seq={seq}",
                source.family()
            )?;
        }
    }
    for tick in 0..ticks {
        let chunk = source.gen_chunk(tick, max_rows);
        for (row, &id) in chunk.ids.iter().enumerate() {
            write!(out, "{tick} {id} ")?;
            match &chunk.data.x {
                XStore::F32 { data, stride } => {
                    push_csv_f32(&mut out, &data[row * stride..(row + 1) * stride])?
                }
                XStore::I32 { data, stride } => {
                    push_csv_i32(&mut out, &data[row * stride..(row + 1) * stride])?
                }
            }
            out.push(' ');
            match &chunk.data.y {
                YStore::F32(v) => write!(out, "{}", v[row])?,
                YStore::I32(v) => write!(out, "{}", v[row])?,
                YStore::Seq { data, stride } => {
                    push_csv_i32(&mut out, &data[row * stride..(row + 1) * stride])?
                }
            }
            out.push('\n');
        }
    }
    Ok(out)
}

fn push_csv_f32(out: &mut String, xs: &[f32]) -> std::fmt::Result {
    use std::fmt::Write as _;
    for (i, x) in xs.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        write!(out, "{x}")?;
    }
    Ok(())
}

fn push_csv_i32(out: &mut String, xs: &[i32]) -> std::fmt::Result {
    use std::fmt::Write as _;
    for (i, x) in xs.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        write!(out, "{x}")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::source::{build_source, StreamKnobs, ALL_STREAMS};

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("ada_streamlog_{name}_{}.log", std::process::id()))
    }

    fn knobs(seed: u64) -> StreamKnobs {
        StreamKnobs { seed, drift_period: 32, burst_period: 8, burst_min: 0.25 }
    }

    #[test]
    fn round_trips_every_generator() {
        for name in ALL_STREAMS {
            let gen = build_source(name, knobs(17)).unwrap();
            let path = tmp(&format!("rt_{name}"));
            write_stream_log(&path, gen.as_ref(), 12, 16).unwrap();
            let file = FileTailSource::open(&path, 0).unwrap();
            assert_eq!(file.family(), gen.family(), "{name}");
            assert_eq!(file.task(), gen.task(), "{name}");
            assert_eq!(file.late_count(), 0, "{name}: in-order log marked late");
            for tick in 0..12u64 {
                let want = gen.gen_chunk(tick, 16);
                let got = file.gen_chunk(tick, 16);
                assert_eq!(got.ids, want.ids, "{name} tick {tick}");
                match (&got.data.x, &want.data.x) {
                    (XStore::F32 { data: a, .. }, XStore::F32 { data: b, .. }) => {
                        assert_eq!(a, b, "{name} tick {tick}")
                    }
                    (XStore::I32 { data: a, .. }, XStore::I32 { data: b, .. }) => {
                        assert_eq!(a, b, "{name} tick {tick}")
                    }
                    _ => panic!("storage mismatch"),
                }
                got.data.validate().unwrap();
            }
            // past the log's end: empty chunks, right shape
            let empty = file.gen_chunk(99, 16);
            assert!(empty.ids.is_empty());
            assert!(empty.data.is_empty());
            std::fs::remove_file(&path).ok();
        }
    }

    #[test]
    fn fetch_looks_up_by_id() {
        let gen = build_source("drift-class", knobs(3)).unwrap();
        let path = tmp("fetch");
        write_stream_log(&path, gen.as_ref(), 6, 8).unwrap();
        let file = FileTailSource::open(&path, 0).unwrap();
        let c2 = file.gen_chunk(2, 8);
        let c4 = file.gen_chunk(4, 8);
        let got = file.fetch(&[c4.ids[0], c2.ids[1], 999_999], 8);
        assert_eq!(got.ids, vec![c2.ids[1], c4.ids[0]]);
        assert_eq!(got.data.len(), 2);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn watermark_reassigns_late_lines() {
        let path = tmp("late");
        let log = "\
#stream-log v1 family=mlp_bike task=reg feat=2
0 0 1.0,2.0 3.0
1 1 1.5,2.5 3.5
5 2 0.5,0.5 1.0
1 3 9.0,9.0 9.0
4 4 4.0,4.0 4.0
";
        std::fs::write(&path, log).unwrap();
        // lateness 2: line with tick 1 after watermark 5 is late (1+2 < 5)
        // and moves to the watermark; the on-time chunk width here is 1,
        // so the overflow spills to tick 6 instead of being dropped
        let file = FileTailSource::open(&path, 2).unwrap();
        assert_eq!(file.late_count(), 1);
        assert_eq!(file.len(), 5);
        assert_eq!(file.gen_chunk(5, 8).ids, vec![2]);
        assert_eq!(file.gen_chunk(6, 8).ids, vec![3], "late id 3 must spill, not drop");
        assert_eq!(file.gen_chunk(1, 8).ids, vec![1]);
        assert_eq!(file.gen_chunk(4, 8).ids, vec![4]);
        assert_eq!(file.max_tick(), 6);

        // lateness 0 (strict): the tick-4 line is late too; both late
        // records chain into the ticks after the watermark
        let strict = FileTailSource::open(&path, 0).unwrap();
        assert_eq!(strict.late_count(), 2);
        assert_eq!(strict.gen_chunk(5, 8).ids, vec![2]);
        assert_eq!(strict.gen_chunk(6, 8).ids, vec![3]);
        assert_eq!(strict.gen_chunk(7, 8).ids, vec![4]);
        assert_eq!(strict.len(), 5);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn max_rows_truncates_buckets() {
        let gen = build_source("drift-reg", knobs(9)).unwrap();
        let path = tmp("trunc");
        write_stream_log(&path, gen.as_ref(), 3, 10).unwrap();
        let file = FileTailSource::open(&path, 0).unwrap();
        let full = file.gen_chunk(0, 10);
        let cut = file.gen_chunk(0, 3);
        assert_eq!(cut.ids, full.ids[..3].to_vec());
        assert_eq!(cut.data.len(), 3);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn malformed_logs_are_rejected() {
        let path = tmp("bad");
        for bad in [
            "not a header\n",
            "#stream-log v1 task=class classes=10 feat=2\n", // no family
            "#stream-log v1 family=unknown task=reg feat=2\n",
            "#stream-log v1 family=mlp_bike task=reg feat=2\n0 7 1.0 2.0\n", // wrong feature arity
            "#stream-log v1 family=mlp_bike task=reg feat=2\n0 7 NaN,1.0 2.0\n", // non-finite feature
            "#stream-log v1 family=mlp_bike task=reg feat=2\n0 7 1.0,1.0 inf\n", // non-finite target
        ] {
            std::fs::write(&path, bad).unwrap();
            assert!(FileTailSource::open(&path, 0).is_err(), "accepted: {bad:?}");
        }
        // duplicate id
        std::fs::write(
            &path,
            "#stream-log v1 family=mlp_bike task=reg feat=2\n0 7 1.0,2.0 3.0\n1 7 1.0,2.0 3.0\n",
        )
        .unwrap();
        assert!(FileTailSource::open(&path, 0).is_err());
        std::fs::remove_file(&path).ok();
    }
}

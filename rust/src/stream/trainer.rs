//! The streaming continuous-training loop: AdaSelection over an unbounded,
//! epochless sample stream.
//!
//! Per tick:
//!   1. the pipeline delivers the tick's chunk, padded to the family batch
//!      size (prefetched and backpressured through the loader's unbounded
//!      mode — stream chunks instead of epoch shuffles, same reorder
//!      window);
//!   2. *prequential* evaluation: the chunk is scored under the current
//!      model before training touches it (rolling-window loss/accuracy);
//!   3. a forward pass produces per-sample (loss, gnorm); the policy picks
//!      the top ⌈γ·arrivals⌉ rows with AdaSelection method weights updated
//!      online;
//!   4. every observation lands in the bounded [`InstanceStore`] (constant
//!      information per instance);
//!   5. a train step runs on the selected rows only.
//!
//! Checkpoints (`Backend::export_state` + policy + store + digest) make a
//! killed run resume with the *exact same* post-resume selection sequence —
//! sources are pure in the tick, so no generator state is persisted.

use std::sync::Arc;

use crate::config::StreamConfig;
use crate::metrics::rolling::{RollingPoint, RollingWindow};
use crate::pipeline::{gather, Batch, BatchProducer, Loader};
use crate::runtime::{Backend, FamilyMeta, NativeBackend, TaskKind};
use crate::selection::bandit::UpdateRule;
use crate::selection::policy::{build_policy, SelectionContext};
use crate::stream::checkpoint::{self, StreamCheckpoint};
use crate::stream::source::{build_source, StreamKnobs, StreamSource};
use crate::stream::store::{InstanceStore, StoreCounters};
use crate::util::timer::{PhaseTimer, Stopwatch};

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv_fold(mut h: u64, x: u64) -> u64 {
    h ^= x;
    h.wrapping_mul(FNV_PRIME)
}

/// Feeds the loader's unbounded mode: batch `id` is stream tick
/// `first_tick + id`, gathered to the family batch size with the chunk's
/// global sample ids in `Batch::indices` (padding repeats the first id,
/// mirroring `gather`'s row padding; `Batch::real` marks the arrivals).
struct ChunkProducer {
    source: Arc<dyn StreamSource>,
    batch: usize,
    first_tick: u64,
    max_ticks: usize,
}

impl BatchProducer for ChunkProducer {
    fn total(&self) -> usize {
        self.max_ticks
    }

    fn produce(&self, id: usize) -> Batch {
        let tick = self.first_tick + id as u64;
        let chunk = self.source.gen_chunk(tick, self.batch);
        let n = chunk.data.len();
        let local: Vec<usize> = (0..n).collect();
        let mut b = gather(&chunk.data, &local, self.batch, 0, id);
        let first = chunk.ids.first().copied().unwrap_or(0);
        let mut ids: Vec<usize> = chunk.ids.iter().map(|&g| g as usize).collect();
        ids.resize(self.batch, first as usize);
        b.indices = ids;
        b
    }
}

/// Result of one stream run (or run segment, when resumed).
pub struct StreamResult {
    pub dataset: String,
    pub selector: String,
    pub gamma: f64,
    pub seed: u64,
    /// ticks processed across the whole run (including pre-resume ticks)
    pub ticks: u64,
    /// samples that arrived (cumulative, checkpoint-carried)
    pub samples_seen: u64,
    /// samples actually trained on (cumulative, checkpoint-carried)
    pub samples_trained: u64,
    /// rolling prequential loss at the end of the run (NaN if eval off)
    pub final_rolling_loss: f32,
    /// rolling prequential accuracy (NaN for regression / eval off)
    pub final_rolling_acc: f32,
    /// periodic rolling-window snapshots (one per eval tick)
    pub rolling: Vec<RollingPoint>,
    /// per-tick digest of the selected global ids (this segment only)
    pub tick_digests: Vec<u64>,
    /// running digest over the whole selection sequence (checkpoint-carried)
    pub digest: u64,
    pub store_len: usize,
    pub store_capacity: usize,
    pub store_counters: StoreCounters,
    /// final AdaSelection method weights, if applicable
    pub weights: Option<Vec<f32>>,
    pub phases: PhaseTimer,
    /// arrivals-per-second over this segment's wall clock
    pub samples_per_sec: f64,
}

/// A stream trainer borrowing a backend for one run.
pub struct StreamTrainer<'b, B: Backend> {
    pub backend: &'b mut B,
    pub cfg: StreamConfig,
    source: Arc<dyn StreamSource>,
    meta: FamilyMeta,
}

impl<'b, B: Backend> StreamTrainer<'b, B> {
    pub fn new(backend: &'b mut B, cfg: StreamConfig) -> anyhow::Result<StreamTrainer<'b, B>> {
        cfg.validate()?;
        backend.validate()?;
        let source = build_source(
            &cfg.dataset,
            StreamKnobs {
                seed: cfg.seed,
                drift_period: cfg.drift_period,
                burst_period: cfg.burst_period,
                burst_min: cfg.burst_min,
            },
        )?;
        let meta = backend.family_meta(source.family())?;
        Ok(StreamTrainer { backend, cfg, source, meta })
    }

    /// Run until `max_ticks` (possibly resuming from a checkpoint).
    pub fn run(&mut self) -> anyhow::Result<StreamResult> {
        let b = self.meta.batch;
        let mut policy = build_policy(
            &self.cfg.selector,
            self.cfg.seed,
            self.cfg.beta,
            self.cfg.cl_on,
            self.cfg.cl_power,
        )?;
        if self.cfg.rule != "eq3" {
            let rule = UpdateRule::parse(&self.cfg.rule)?;
            if let Some(ada) = policy.as_ada() {
                ada.state_mut().set_rule(rule);
            }
        }
        let store = InstanceStore::new(self.cfg.store_capacity, self.cfg.store_shards);
        let mut first_tick: u64 = 0;
        let mut digest = FNV_OFFSET;
        let mut samples_seen = 0u64;
        let mut samples_trained = 0u64;

        let mut state = if self.cfg.resume {
            let path = self
                .cfg
                .checkpoint
                .as_ref()
                .ok_or_else(|| anyhow::anyhow!("--resume requires --checkpoint FILE"))?;
            let ck = checkpoint::load(path)?;
            anyhow::ensure!(
                ck.family == self.meta.name,
                "checkpoint family '{}' does not match stream family '{}'",
                ck.family,
                self.meta.name
            );
            let identity = self.cfg.identity_json();
            anyhow::ensure!(
                ck.identity == identity,
                "checkpoint was written by a different run \
                 (saved {} vs configured {}) — seed/stream/selector/drift \
                 knobs must match for a deterministic continuation",
                ck.identity,
                identity
            );
            checkpoint::restore_policy(&mut policy, &ck.policy)?;
            store.load(&ck.store);
            first_tick = ck.tick;
            digest = ck.digest;
            samples_seen = ck.samples_seen;
            samples_trained = ck.samples_trained;
            log::info!("resumed from {path:?} at tick {first_tick}");
            self.backend.import_state(&self.meta.name, &ck.tensors)?
        } else {
            self.backend.init_state(&self.meta.name, self.cfg.seed as i32)?
        };
        anyhow::ensure!(
            (first_tick as usize) < self.cfg.max_ticks,
            "checkpoint tick {first_tick} already at max_ticks {}",
            self.cfg.max_ticks
        );

        // keep any backend compile step out of the timed loop
        let k_full = ((self.cfg.gamma * b as f64).ceil() as usize).clamp(1, b);
        let sizes: Vec<usize> =
            if policy.is_benchmark() { vec![b] } else { vec![k_full, b] };
        self.backend.preload_family(&self.meta.name, &sizes)?;

        let producer: Arc<dyn BatchProducer> = Arc::new(ChunkProducer {
            source: self.source.clone(),
            batch: b,
            first_tick,
            max_ticks: self.cfg.max_ticks - first_tick as usize,
        });
        let mut loader = Loader::from_producer(producer, self.cfg.workers, self.cfg.capacity);

        log::info!(
            "stream start: backend={} stream={} selector={} γ={} B={} ticks={}..{} store={} workers={}",
            self.backend.name(),
            self.cfg.dataset,
            policy.name(),
            self.cfg.gamma,
            b,
            first_tick,
            self.cfg.max_ticks,
            store.capacity(),
            self.cfg.workers
        );

        let mut roll_loss = RollingWindow::new(self.cfg.window);
        let mut roll_acc = RollingWindow::new(self.cfg.window);
        let mut rolling: Vec<RollingPoint> = Vec::new();
        let mut tick_digests: Vec<u64> = Vec::new();
        let mut phases = PhaseTimer::default();
        let clock = Stopwatch::new();
        let mut seen_this_segment = 0u64;
        let mut tick = first_tick;

        loop {
            let batch = {
                let t0 = std::time::Instant::now();
                let batch = loader.next_batch();
                phases.add("data", t0.elapsed());
                match batch {
                    Some(batch) => batch,
                    None => break,
                }
            };
            let real = batch.real;
            samples_seen += real as u64;
            seen_this_segment += real as u64;

            // prequential test-then-train: score the arrivals before any
            // of them is trained on (absolute cadence so resume keeps the
            // same eval ticks)
            if self.cfg.eval_every > 0 && tick % self.cfg.eval_every as u64 == 0 {
                let (loss_sum, correct) =
                    phases.time("eval", || self.backend.eval(&state, &batch))?;
                roll_loss.push(loss_sum as f64 / real as f64);
                if self.meta.task != TaskKind::Regression {
                    roll_acc.push(correct as f64 / real as f64);
                }
                rolling.push(RollingPoint {
                    tick,
                    loss: roll_loss.mean() as f32,
                    acc: roll_acc.mean() as f32,
                });
            }

            let k = ((self.cfg.gamma * real as f64).ceil() as usize).clamp(1, real);
            let selected: Vec<usize> = if policy.is_benchmark() {
                (0..real).collect()
            } else {
                // forward + score: fused on the backend scorer for
                // AdaSelection, separate passes otherwise. α/scores are
                // computed over the padded batch (compiled-shape friendly)
                // and sliced to the real arrivals before selection.
                let fused = match policy.as_ada() {
                    Some(ada) => {
                        let w_full = ada.state().full_weights();
                        let t_next = ada.state().iteration() + 1;
                        let (cl_on, cl_power) = {
                            let c = ada.state().config();
                            (c.cl_on, c.cl_power)
                        };
                        phases.time("forward", || {
                            self.backend.forward_score_fused(
                                &state, &batch, &w_full, t_next, cl_power, cl_on,
                            )
                        })?
                    }
                    None => None,
                };
                let (sel, loss_real, gnorm_real) = match fused {
                    Some(f) => {
                        let loss_real = f.loss[..real].to_vec();
                        let gnorm_real = f.gnorm[..real].to_vec();
                        let scores = f.scores[..real].to_vec();
                        let alphas: Vec<Vec<f32>> =
                            f.alphas.iter().map(|row| row[..real].to_vec()).collect();
                        let t0 = std::time::Instant::now();
                        let ada = policy.as_ada().expect("fused path is ada-only");
                        let sel = ada.select_kernel(&loss_real, &alphas, scores, k);
                        phases.add("select", t0.elapsed());
                        (sel, loss_real, gnorm_real)
                    }
                    None => {
                        let (loss, gnorm) = phases
                            .time("forward", || self.backend.forward_scores(&state, &batch))?;
                        let loss_real = loss[..real].to_vec();
                        let gnorm_real = gnorm[..real].to_vec();
                        let t0 = std::time::Instant::now();
                        let sel = policy.select(&SelectionContext {
                            loss: &loss_real,
                            gnorm: &gnorm_real,
                            k,
                        });
                        phases.add("select", t0.elapsed());
                        (sel, loss_real, gnorm_real)
                    }
                };
                // constant information per instance: record every arrival
                let t0 = std::time::Instant::now();
                let tick32 = tick.min(u32::MAX as u64) as u32;
                for ((&id, &l), &g) in batch.indices[..real]
                    .iter()
                    .zip(loss_real.iter())
                    .zip(gnorm_real.iter())
                {
                    store.update(id as u64, l, g, tick32);
                }
                phases.add("store", t0.elapsed());
                sel
            };

            let sub = batch.gather_rows(&selected);
            phases.time("update", || {
                self.backend.train_step(&mut state, &sub, self.cfg.lr)
            })?;
            samples_trained += selected.len() as u64;

            let mut h = FNV_OFFSET;
            for &row in &selected {
                h = fnv_fold(h, batch.indices[row] as u64);
            }
            tick_digests.push(h);
            digest = fnv_fold(digest, h);

            tick += 1;
            if let Some(path) = &self.cfg.checkpoint {
                let every = self.cfg.checkpoint_every as u64;
                let at_end = tick as usize == self.cfg.max_ticks;
                if at_end || (every > 0 && (tick - first_tick) % every == 0) {
                    let ck = StreamCheckpoint {
                        tick,
                        family: self.meta.name.clone(),
                        identity: self.cfg.identity_json(),
                        tensors: self.backend.export_state(&state)?,
                        policy: checkpoint::policy_to_json(&policy),
                        store: store.snapshot(),
                        digest,
                        samples_seen,
                        samples_trained,
                    };
                    phases.time("checkpoint", || checkpoint::save(path, &ck))?;
                }
            }
            if self.cfg.window > 0 && tick % self.cfg.window as u64 == 0 {
                log::info!(
                    "tick {tick}: rolling_loss={:.4} rolling_acc={:.4} store={}/{} seen={}",
                    roll_loss.mean(),
                    roll_acc.mean(),
                    store.len(),
                    store.capacity(),
                    samples_seen
                );
            }
        }

        let elapsed = clock.elapsed_secs();
        Ok(StreamResult {
            dataset: self.cfg.dataset.clone(),
            selector: policy.name(),
            gamma: self.cfg.gamma,
            seed: self.cfg.seed,
            ticks: tick,
            samples_seen,
            samples_trained,
            final_rolling_loss: roll_loss.mean() as f32,
            final_rolling_acc: roll_acc.mean() as f32,
            rolling,
            tick_digests,
            digest,
            store_len: store.len(),
            store_capacity: store.capacity(),
            store_counters: store.counters(),
            weights: policy.weights(),
            phases,
            samples_per_sec: seen_this_segment as f64 / elapsed.max(1e-9),
        })
    }
}

/// Convenience: run one stream job on a fresh backend picked by
/// `cfg.backend`.
pub fn run(cfg: StreamConfig) -> anyhow::Result<StreamResult> {
    match cfg.backend.as_str() {
        "native" => {
            let mut backend = NativeBackend::new();
            StreamTrainer::new(&mut backend, cfg)?.run()
        }
        "xla" => run_xla(cfg),
        other => anyhow::bail!("unknown backend '{other}' (expected native|xla)"),
    }
}

#[cfg(feature = "xla")]
fn run_xla(cfg: StreamConfig) -> anyhow::Result<StreamResult> {
    let mut engine = crate::runtime::Engine::new(&cfg.artifacts_dir)?;
    StreamTrainer::new(&mut engine, cfg)?.run()
}

#[cfg(not(feature = "xla"))]
fn run_xla(_cfg: StreamConfig) -> anyhow::Result<StreamResult> {
    anyhow::bail!("backend 'xla' requires building with `--features xla`")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_producer_pads_and_carries_global_ids() {
        let source = build_source(
            "drift-class",
            StreamKnobs { seed: 3, drift_period: 64, burst_period: 8, burst_min: 0.25 },
        )
        .unwrap();
        let p = ChunkProducer { source, batch: 16, first_tick: 5, max_ticks: 100 };
        assert_eq!(p.total(), 100);
        let b = p.produce(0); // tick 5
        assert_eq!(b.len(), 16);
        assert!(b.real >= 1 && b.real <= 16);
        // global ids of tick 5 under chunk width 16 start at 80
        assert_eq!(b.indices[0], 80);
        for (row, &id) in b.indices[..b.real].iter().enumerate() {
            assert_eq!(id, 80 + row);
        }
        // padding repeats the first id
        for &id in &b.indices[b.real..] {
            assert_eq!(id, 80);
        }
    }

    #[test]
    fn producer_is_pure_per_id() {
        let source = build_source(
            "drift-reg",
            StreamKnobs { seed: 9, drift_period: 32, burst_period: 4, burst_min: 0.5 },
        )
        .unwrap();
        let p = ChunkProducer { source, batch: 10, first_tick: 0, max_ticks: 50 };
        let a = p.produce(7);
        let b = p.produce(7);
        assert_eq!(a.indices, b.indices);
        assert_eq!(a.x_f32, b.x_f32);
        assert_eq!(a.y_f32, b.y_f32);
    }

    #[test]
    fn fnv_fold_distinguishes_sequences() {
        let a = [1u64, 2, 3].iter().fold(FNV_OFFSET, |h, &x| fnv_fold(h, x));
        let b = [3u64, 2, 1].iter().fold(FNV_OFFSET, |h, &x| fnv_fold(h, x));
        assert_ne!(a, b);
    }
}

//! Unbounded synthetic production-traffic sources (the paper's motivating
//! "continuous training with vast amounts of data" scenario).
//!
//! A [`StreamSource`] is an epochless generator: tick `t` yields a chunk of
//! freshly-arrived samples with globally unique `u64` ids. Generation is a
//! *pure function of `(seed, tick, row)`* — no mutable cursor — which is
//! what lets the loader's workers materialize chunks concurrently and out
//! of order (the reorder window restores sequence order) and what makes
//! checkpoint/resume trivial: resuming at tick `t` regenerates byte-
//! identical traffic with no source state to persist.
//!
//! All three task types ship a generator:
//!
//! | name          | task                  | family         | drift mechanism |
//! |---------------|-----------------------|----------------|-----------------|
//! | `drift-class` | classification (10)   | `stream_class` | class prototypes rotate `base → alt`; a static easy subpopulation stays learnable |
//! | `drift-reg`   | regression            | `mlp_bike`     | target weight vector rotates `base → alt` |
//! | `drift-lm`    | next-token LM         | `transformer`  | token transitions interpolate between two Markov seeds |
//!
//! Arrival-rate bursts: chunk sizes follow a sinusoid between
//! `burst_min · B` and `B` with period `burst_period` ticks, modelling
//! diurnal traffic. Padding/masking downstream handles partial chunks.

use std::sync::Arc;

use crate::data::{Dataset, Task, XStore, YStore};
use crate::util::rng::Pcg64;

/// One tick's arrivals.
pub struct StreamChunk {
    /// globally unique sample ids (`tick · B + row`)
    pub ids: Vec<u64>,
    /// dense chunk data, one row per id
    pub data: Dataset,
}

/// An unbounded, epochless sample stream.
pub trait StreamSource: Send + Sync {
    /// Stream name as registered in [`build_source`].
    fn name(&self) -> &'static str;

    /// Model family this stream trains (native backend family table).
    fn family(&self) -> &'static str;

    fn task(&self) -> Task;

    /// Materialize tick `t`'s arrivals: between `⌈burst_min·max_rows⌉` and
    /// `max_rows` samples. Must be pure in `(self, tick)` — loader workers
    /// call this concurrently and out of order.
    fn gen_chunk(&self, tick: u64, max_rows: usize) -> StreamChunk;

    /// Re-materialize specific instances by global id (the replay
    /// scheduler's path). The default regenerates through
    /// [`StreamSource::gen_chunk`] — valid because generation is pure in
    /// `(seed, tick)` and ids encode `(tick, row)` under chunk width
    /// `max_rows`. Ids the source never produced are silently skipped, so
    /// the returned chunk may be smaller than `ids` (or empty). Output
    /// rows are ordered by (tick, row). Cost note: each distinct tick
    /// regenerates its whole chunk to extract a few rows — fine at replay
    /// deficits (≤ B ids per lull tick); sources with cheap random access
    /// (e.g. the file tail) override this with a direct id lookup.
    fn fetch(&self, ids: &[u64], max_rows: usize) -> StreamChunk {
        let width = max_rows.max(1) as u64;
        let mut groups: std::collections::BTreeMap<u64, Vec<usize>> =
            std::collections::BTreeMap::new();
        for &id in ids {
            groups.entry(id / width).or_default().push((id % width) as usize);
        }
        let mut out: Option<Dataset> = None;
        let mut out_ids: Vec<u64> = Vec::new();
        for (tick, mut rows) in groups {
            rows.sort_unstable();
            rows.dedup();
            let chunk = self.gen_chunk(tick, max_rows);
            rows.retain(|&r| r < chunk.data.len());
            if rows.is_empty() {
                continue;
            }
            out_ids.extend(rows.iter().map(|&r| chunk.ids[r]));
            let part = chunk.data.select_rows(&rows);
            match &mut out {
                None => out = Some(part),
                Some(acc) => acc.append(&part),
            }
        }
        let data = out.unwrap_or_else(|| self.gen_chunk(0, 1).data.select_rows(&[]));
        StreamChunk { ids: out_ids, data }
    }
}

/// Drift/burst knobs shared by every generator.
#[derive(Clone, Debug)]
pub struct StreamKnobs {
    pub seed: u64,
    /// ticks per full concept-drift cycle; 0 = stationary
    pub drift_period: u64,
    /// arrival-rate modulation period in ticks; 0 = constant full chunks
    pub burst_period: u64,
    /// fraction of `max_rows` arriving at the deepest lull, in (0, 1]
    pub burst_min: f64,
}

impl StreamKnobs {
    /// Sinusoidal arrival count in `[burst_min·max_rows, max_rows]`.
    fn arrivals(&self, tick: u64, max_rows: usize) -> usize {
        if self.burst_period == 0 {
            return max_rows.max(1);
        }
        let phase = (tick % self.burst_period) as f64 / self.burst_period as f64;
        let level = self.burst_min
            + (1.0 - self.burst_min) * 0.5 * (1.0 + (std::f64::consts::TAU * phase).sin());
        ((max_rows as f64 * level).round() as usize).clamp(1, max_rows)
    }

    /// Drift phase angle θ ∈ [0, TAU) at `tick`.
    fn theta(&self, tick: u64) -> f64 {
        if self.drift_period == 0 {
            0.0
        } else {
            std::f64::consts::TAU * (tick % self.drift_period) as f64
                / self.drift_period as f64
        }
    }

    /// The per-sample generator stream: depends only on (seed, id, salt).
    fn rng_for(&self, id: u64, salt: u64) -> Pcg64 {
        Pcg64::new(
            self.seed
                ^ id.wrapping_mul(0x9e37_79b9_7f4a_7c15)
                ^ salt.rotate_left(17),
        )
    }
}

/// Globally unique id of `(tick, row)` under chunk width `max_rows`.
fn global_id(tick: u64, row: usize, max_rows: usize) -> u64 {
    tick.wrapping_mul(max_rows as u64).wrapping_add(row as u64)
}

// ---------------------------------------------------------------------------
// drift-class
// ---------------------------------------------------------------------------

const CLASS_COUNT: usize = 10;
const CLASS_FEAT: usize = 32;

/// Classification traffic with a drifting and a static subpopulation.
///
/// Half the arrivals are *easy*: tight noise around a static per-class
/// prototype — learned once, they stay learned. The other half are *hard*:
/// drawn around a prototype that rotates `base → alt` with the drift phase,
/// so they are a persistent source of fresh error. Loss-aware selection
/// concentrates its ⌈γB⌉ budget on the drifting half and tracks the
/// rotation faster than uniform subsampling (the stream-cmp experiment and
/// `tests/stream_e2e.rs` measure exactly this).
pub struct DriftClassSource {
    knobs: StreamKnobs,
    /// static per-class prototypes, `CLASS_COUNT × CLASS_FEAT`
    base: Vec<f32>,
    /// drift-target prototypes, same shape
    alt: Vec<f32>,
}

impl DriftClassSource {
    pub fn new(knobs: StreamKnobs) -> DriftClassSource {
        let mut rng = Pcg64::new(knobs.seed ^ 0xc1a5_51f1_ed00_0001);
        let n = CLASS_COUNT * CLASS_FEAT;
        let base: Vec<f32> = (0..n).map(|_| rng.normal_ms(0.0, 1.0) as f32).collect();
        let alt: Vec<f32> = (0..n).map(|_| rng.normal_ms(0.0, 1.0) as f32).collect();
        DriftClassSource { knobs, base, alt }
    }
}

impl StreamSource for DriftClassSource {
    fn name(&self) -> &'static str {
        "drift-class"
    }

    fn family(&self) -> &'static str {
        "stream_class"
    }

    fn task(&self) -> Task {
        Task::Classification { classes: CLASS_COUNT }
    }

    fn gen_chunk(&self, tick: u64, max_rows: usize) -> StreamChunk {
        let n = self.knobs.arrivals(tick, max_rows);
        let theta = self.knobs.theta(tick);
        let (cos_t, sin_t) = (theta.cos() as f32, theta.sin() as f32);
        let mut x = Vec::with_capacity(n * CLASS_FEAT);
        let mut y = Vec::with_capacity(n);
        let mut ids = Vec::with_capacity(n);
        for row in 0..n {
            let id = global_id(tick, row, max_rows);
            let mut rng = self.knobs.rng_for(id, 0x11);
            let cls = rng.next_below(CLASS_COUNT as u64) as usize;
            let easy = rng.next_f64() < 0.5;
            let off = cls * CLASS_FEAT;
            if easy {
                for j in 0..CLASS_FEAT {
                    x.push(self.base[off + j] + 0.15 * rng.normal() as f32);
                }
            } else {
                for j in 0..CLASS_FEAT {
                    let proto = cos_t * self.base[off + j] + sin_t * self.alt[off + j];
                    x.push(proto + 0.45 * rng.normal() as f32);
                }
            }
            y.push(cls as i32);
            ids.push(id);
        }
        StreamChunk {
            ids,
            data: Dataset {
                name: "drift-class".into(),
                task: Task::Classification { classes: CLASS_COUNT },
                feat_shape: vec![CLASS_FEAT],
                x: XStore::F32 { data: x, stride: CLASS_FEAT },
                y: YStore::I32(y),
            },
        }
    }
}

// ---------------------------------------------------------------------------
// drift-reg
// ---------------------------------------------------------------------------

const REG_FEAT: usize = 8;

/// Regression traffic: `y = w(t)·x + ε` with the weight vector rotating
/// `base → alt` over the drift period.
pub struct DriftRegSource {
    knobs: StreamKnobs,
    base_w: Vec<f32>,
    alt_w: Vec<f32>,
}

impl DriftRegSource {
    pub fn new(knobs: StreamKnobs) -> DriftRegSource {
        let mut rng = Pcg64::new(knobs.seed ^ 0xc1a5_51f1_ed00_0002);
        let base_w: Vec<f32> = (0..REG_FEAT).map(|_| rng.normal_ms(0.0, 1.0) as f32).collect();
        let alt_w: Vec<f32> = (0..REG_FEAT).map(|_| rng.normal_ms(0.0, 1.0) as f32).collect();
        DriftRegSource { knobs, base_w, alt_w }
    }
}

impl StreamSource for DriftRegSource {
    fn name(&self) -> &'static str {
        "drift-reg"
    }

    fn family(&self) -> &'static str {
        "mlp_bike"
    }

    fn task(&self) -> Task {
        Task::Regression
    }

    fn gen_chunk(&self, tick: u64, max_rows: usize) -> StreamChunk {
        let n = self.knobs.arrivals(tick, max_rows);
        let theta = self.knobs.theta(tick);
        let (cos_t, sin_t) = (theta.cos() as f32, theta.sin() as f32);
        let mut x = Vec::with_capacity(n * REG_FEAT);
        let mut y = Vec::with_capacity(n);
        let mut ids = Vec::with_capacity(n);
        for row in 0..n {
            let id = global_id(tick, row, max_rows);
            let mut rng = self.knobs.rng_for(id, 0x22);
            let mut target = 0.0f32;
            for j in 0..REG_FEAT {
                let xv = rng.normal() as f32;
                let wj = cos_t * self.base_w[j] + sin_t * self.alt_w[j];
                target += wj * xv;
                x.push(xv);
            }
            y.push(target + 0.1 * rng.normal() as f32);
            ids.push(id);
        }
        StreamChunk {
            ids,
            data: Dataset {
                name: "drift-reg".into(),
                task: Task::Regression,
                feat_shape: vec![REG_FEAT],
                x: XStore::F32 { data: x, stride: REG_FEAT },
                y: YStore::F32(y),
            },
        }
    }
}

// ---------------------------------------------------------------------------
// drift-lm
// ---------------------------------------------------------------------------

const LM_VOCAB: usize = 256;
const LM_SEQ: usize = 32;

/// Next-token traffic: order-2 hash-chain transitions that interpolate
/// between two Markov seeds as the drift phase advances (topic shift).
pub struct DriftLmSource {
    knobs: StreamKnobs,
}

impl DriftLmSource {
    pub fn new(knobs: StreamKnobs) -> DriftLmSource {
        DriftLmSource { knobs }
    }

    fn next_tok(model_seed: u64, a: i32, b: i32, rng: &mut Pcg64) -> i32 {
        // splitmix-style avalanche over (seed, context pair)
        let z = crate::util::rng::avalanche(
            model_seed
                ^ (a as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15)
                ^ (b as u64).rotate_left(32),
        );
        // geometric pick among 4 hash-derived successors keeps per-context
        // entropy low (learnable) but nonzero
        let mut pick = 0usize;
        for i in 0..3 {
            if rng.next_f64() < 0.5 {
                pick = i;
                break;
            }
            pick = i + 1;
        }
        ((z >> (pick * 8)) % LM_VOCAB as u64) as i32
    }
}

impl StreamSource for DriftLmSource {
    fn name(&self) -> &'static str {
        "drift-lm"
    }

    fn family(&self) -> &'static str {
        "transformer"
    }

    fn task(&self) -> Task {
        Task::Lm { vocab: LM_VOCAB, seq: LM_SEQ }
    }

    fn gen_chunk(&self, tick: u64, max_rows: usize) -> StreamChunk {
        let n = self.knobs.arrivals(tick, max_rows);
        let theta = self.knobs.theta(tick);
        // fraction of transitions drawn from the second topic model
        let mix = 0.5 * (1.0 - theta.cos());
        let seed_a = self.knobs.seed ^ 0xaaaa_1111_2222_3333;
        let seed_b = self.knobs.seed ^ 0xbbbb_4444_5555_6666;
        let mut x = vec![0i32; n * LM_SEQ];
        let mut y = vec![0i32; n * LM_SEQ];
        let mut ids = Vec::with_capacity(n);
        for row in 0..n {
            let id = global_id(tick, row, max_rows);
            let mut rng = self.knobs.rng_for(id, 0x33);
            let mut toks = [0i32; LM_SEQ + 1];
            toks[0] = rng.next_below(LM_VOCAB as u64) as i32;
            toks[1] = rng.next_below(LM_VOCAB as u64) as i32;
            for t in 2..LM_SEQ + 1 {
                let seed = if rng.next_f64() < mix { seed_b } else { seed_a };
                toks[t] = Self::next_tok(seed, toks[t - 2], toks[t - 1], &mut rng);
            }
            x[row * LM_SEQ..(row + 1) * LM_SEQ].copy_from_slice(&toks[..LM_SEQ]);
            y[row * LM_SEQ..(row + 1) * LM_SEQ].copy_from_slice(&toks[1..]);
            ids.push(id);
        }
        StreamChunk {
            ids,
            data: Dataset {
                name: "drift-lm".into(),
                task: Task::Lm { vocab: LM_VOCAB, seq: LM_SEQ },
                feat_shape: vec![LM_SEQ],
                x: XStore::I32 { data: x, stride: LM_SEQ },
                y: YStore::Seq { data: y, stride: LM_SEQ },
            },
        }
    }
}

// ---------------------------------------------------------------------------
// registry
// ---------------------------------------------------------------------------

/// All stream names, one per task type.
pub const ALL_STREAMS: [&str; 3] = ["drift-class", "drift-reg", "drift-lm"];

/// Which model family serves each stream (mirrors `data::family_for`).
/// `file:PATH` resolves by reading the log's header. `tcp:ADDR` cannot be
/// resolved without consuming the feed, so validation only checks the
/// address shape and returns a placeholder — the real family comes from
/// the header at [`build_source`] time (callers always take the family
/// from the built source).
pub fn family_for(name: &str) -> anyhow::Result<&'static str> {
    if let Some(path) = name.strip_prefix("file:") {
        let src = crate::stream::file_source::FileTailSource::open(
            std::path::Path::new(path),
            crate::stream::file_source::DEFAULT_LATENESS,
        )?;
        return Ok(src.family());
    }
    if let Some(addr) = name.strip_prefix("tcp:") {
        anyhow::ensure!(
            addr.rsplit_once(':').map_or(false, |(h, p)| {
                !h.is_empty() && p.parse::<u16>().is_ok()
            }),
            "tcp stream spec '{name}' is not HOST:PORT"
        );
        return Ok("(tcp feed: family resolved at connect)");
    }
    Ok(match name {
        "drift-class" => "stream_class",
        "drift-reg" => "mlp_bike",
        "drift-lm" => "transformer",
        other => anyhow::bail!(
            "unknown stream '{other}' (expected drift-class|drift-reg|drift-lm|file:PATH|tcp:ADDR)"
        ),
    })
}

/// Build a registered stream source. `file:PATH` opens a line-delimited
/// stream log (see `stream::file_source`) with the default lateness
/// window; `tcp:ADDR` ingests the same format once from a TCP producer
/// (see `stream::socket_source`). The seeded drift knobs do not apply to
/// captured feeds.
pub fn build_source(name: &str, knobs: StreamKnobs) -> anyhow::Result<Arc<dyn StreamSource>> {
    if let Some(path) = name.strip_prefix("file:") {
        return Ok(Arc::new(crate::stream::file_source::FileTailSource::open(
            std::path::Path::new(path),
            crate::stream::file_source::DEFAULT_LATENESS,
        )?));
    }
    if let Some(addr) = name.strip_prefix("tcp:") {
        return Ok(Arc::new(crate::stream::socket_source::SocketTailSource::connect(
            addr,
            crate::stream::file_source::DEFAULT_LATENESS,
        )?));
    }
    Ok(match name {
        "drift-class" => Arc::new(DriftClassSource::new(knobs)),
        "drift-reg" => Arc::new(DriftRegSource::new(knobs)),
        "drift-lm" => Arc::new(DriftLmSource::new(knobs)),
        other => anyhow::bail!(
            "unknown stream '{other}' (expected drift-class|drift-reg|drift-lm|file:PATH|tcp:ADDR)"
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn knobs(seed: u64) -> StreamKnobs {
        StreamKnobs { seed, drift_period: 64, burst_period: 16, burst_min: 0.25 }
    }

    #[test]
    fn registry_builds_all_streams() {
        for name in ALL_STREAMS {
            let s = build_source(name, knobs(3)).unwrap();
            assert_eq!(s.name(), name);
            assert_eq!(s.family(), family_for(name).unwrap());
            let chunk = s.gen_chunk(5, 32);
            assert!(!chunk.ids.is_empty());
            assert_eq!(chunk.ids.len(), chunk.data.len());
            chunk.data.validate().unwrap();
        }
        assert!(build_source("nope", knobs(0)).is_err());
        assert!(family_for("nope").is_err());
    }

    #[test]
    fn generation_is_pure_in_tick() {
        for name in ALL_STREAMS {
            let s = build_source(name, knobs(7)).unwrap();
            let a = s.gen_chunk(11, 24);
            let b = s.gen_chunk(11, 24);
            assert_eq!(a.ids, b.ids, "{name}");
            match (&a.data.x, &b.data.x) {
                (XStore::F32 { data: da, .. }, XStore::F32 { data: db, .. }) => {
                    assert_eq!(da, db, "{name}")
                }
                (XStore::I32 { data: da, .. }, XStore::I32 { data: db, .. }) => {
                    assert_eq!(da, db, "{name}")
                }
                _ => panic!("storage mismatch"),
            }
        }
    }

    #[test]
    fn ids_are_globally_unique_across_ticks() {
        let s = build_source("drift-class", knobs(1)).unwrap();
        let mut seen = std::collections::HashSet::new();
        for tick in 0..50u64 {
            for id in s.gen_chunk(tick, 16).ids {
                assert!(seen.insert(id), "duplicate id {id} at tick {tick}");
            }
        }
    }

    #[test]
    fn bursts_modulate_arrivals_within_bounds() {
        let k = StreamKnobs { seed: 0, drift_period: 0, burst_period: 8, burst_min: 0.25 };
        let s = DriftClassSource::new(k);
        let sizes: Vec<usize> = (0..8).map(|t| s.gen_chunk(t, 100).ids.len()).collect();
        assert!(sizes.iter().all(|&n| (25..=100).contains(&n)), "{sizes:?}");
        assert!(sizes.iter().any(|&n| n < 100), "no lull in {sizes:?}");
        assert!(sizes.iter().any(|&n| n == 100), "no burst peak in {sizes:?}");
    }

    #[test]
    fn no_burst_period_means_constant_full_chunks() {
        let k = StreamKnobs { seed: 0, drift_period: 32, burst_period: 0, burst_min: 0.5 };
        let s = DriftRegSource::new(k);
        for t in 0..10u64 {
            assert_eq!(s.gen_chunk(t, 40).ids.len(), 40);
        }
    }

    #[test]
    fn fetch_regenerates_exact_rows() {
        for name in ALL_STREAMS {
            let s = build_source(name, knobs(13)).unwrap();
            let chunk = s.gen_chunk(9, 20);
            // ask for a scattered subset (plus one id that never existed)
            let want: Vec<u64> = vec![chunk.ids[2], chunk.ids[0], 9 * 20 + 19_999];
            let got = s.fetch(&want, 20);
            assert_eq!(got.ids, vec![chunk.ids[0], chunk.ids[2]], "{name}");
            assert_eq!(got.data.len(), 2, "{name}");
            got.data.validate().unwrap();
            let expect = chunk.data.select_rows(&[0, 2]);
            match (&got.data.x, &expect.x) {
                (XStore::F32 { data: a, .. }, XStore::F32 { data: b, .. }) => {
                    assert_eq!(a, b, "{name}")
                }
                (XStore::I32 { data: a, .. }, XStore::I32 { data: b, .. }) => {
                    assert_eq!(a, b, "{name}")
                }
                _ => panic!("storage mismatch"),
            }
        }
    }

    #[test]
    fn fetch_spans_ticks_and_handles_empty() {
        let s = build_source("drift-class", knobs(5)).unwrap();
        let a = s.gen_chunk(3, 16);
        let b = s.gen_chunk(7, 16);
        let got = s.fetch(&[b.ids[1], a.ids[0]], 16);
        // output is (tick, row)-ordered regardless of request order
        assert_eq!(got.ids, vec![a.ids[0], b.ids[1]]);
        let empty = s.fetch(&[], 16);
        assert!(empty.ids.is_empty());
        assert!(empty.data.is_empty());
    }

    #[test]
    fn drift_moves_the_concept() {
        // the hard-subpopulation prototypes at opposite drift phases must
        // differ while the same tick reproduces itself (checked above)
        let k = StreamKnobs { seed: 5, drift_period: 100, burst_period: 0, burst_min: 1.0 };
        let s = DriftClassSource::new(k);
        let early = s.gen_chunk(0, 64);
        let late = s.gen_chunk(50, 64); // θ = π: prototypes at -base
        let (XStore::F32 { data: xe, .. }, XStore::F32 { data: xl, .. }) =
            (&early.data.x, &late.data.x)
        else {
            panic!("expected f32 stores");
        };
        assert_ne!(xe, xl);
    }
}

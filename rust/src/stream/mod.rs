//! Streaming continuous-training subsystem (the paper's motivating
//! production scenario: "continuous training with vast amounts of data",
//! handled by "recording a constant amount of information per instance").
//!
//!   * [`source`] — the [`source::StreamSource`] trait + seeded synthetic
//!     production-traffic generators for all three task types, with
//!     configurable concept drift and arrival-rate bursts;
//!   * [`store`] — the sharded, hard-capacity-bounded
//!     [`store::InstanceStore`] of fixed per-instance records (also the
//!     substrate of the batch trainer's stale-loss cache);
//!   * [`trainer`] — the [`trainer::StreamTrainer`] driving the pipeline
//!     loader's unbounded mode through any `Backend`, selecting ⌈γB⌉ per
//!     micro-batch with AdaSelection weights updated online;
//!   * [`checkpoint`] — deterministic kill/resume of (model state, policy
//!     state, store).
//!
//! CLI surface: `adaselection stream --dataset drift-class --gamma 0.5`.

pub mod checkpoint;
pub mod source;
pub mod store;
pub mod trainer;

pub use source::{build_source, StreamChunk, StreamKnobs, StreamSource, ALL_STREAMS};
pub use store::{InstanceRecord, InstanceStore, StoreCounters, BYTES_PER_INSTANCE};
pub use trainer::{run, StreamResult, StreamTrainer};

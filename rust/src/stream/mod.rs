//! Streaming continuous-training subsystem (the paper's motivating
//! production scenario: "continuous training with vast amounts of data",
//! handled by "recording a constant amount of information per instance").
//!
//!   * [`source`] — the [`source::StreamSource`] trait + seeded synthetic
//!     production-traffic generators for all three task types, with
//!     configurable concept drift and arrival-rate bursts;
//!   * [`file_source`] — the same trait over a line-delimited log file
//!     with late-arrival watermarking (`--dataset file:PATH`);
//!   * [`socket_source`] — the same `#stream-log v1` format ingested once
//!     from a TCP producer (`--dataset tcp:ADDR`);
//!   * [`store`] — the sharded, hard-capacity-bounded
//!     [`store::InstanceStore`] of fixed per-instance records (also the
//!     substrate of the batch trainer's stale-loss cache), with the
//!     freshest-tick-wins merge the cluster gossips through;
//!   * [`tick`] — the per-tick training kernel ([`tick::TickEngine`])
//!     shared by the single-process trainer and the cluster nodes:
//!     prequential eval, fused scoring, Page–Hinkley drift control of γ
//!     and the method-weight rate, store bookkeeping, replay top-up;
//!   * [`trainer`] — the [`trainer::StreamTrainer`] driving the pipeline
//!     loader's unbounded mode through any `Backend`;
//!   * [`checkpoint`] — deterministic kill/resume of (model state, policy
//!     state, store, drift state).
//!
//! CLI surface: `adaselection stream --dataset drift-class --gamma 0.5
//! [--drift-detect] [--replay]`.

pub mod checkpoint;
pub mod file_source;
pub mod socket_source;
pub mod source;
pub mod store;
pub mod tick;
pub mod trainer;

pub use file_source::{stream_log_text, write_stream_log, FileTailSource};
pub use socket_source::{serve_once, SocketTailSource};
pub use source::{build_source, StreamChunk, StreamKnobs, StreamSource, ALL_STREAMS};
pub use store::{InstanceRecord, InstanceStore, StoreCounters, BYTES_PER_INSTANCE};
pub use tick::{DriftGamma, DriftKind, TickEngine, TickOutcome};
pub use trainer::{run, StreamResult, StreamTrainer};

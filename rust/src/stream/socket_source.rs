//! [`SocketTailSource`]: a [`StreamSource`] tailing a TCP feed
//! (`--dataset tcp:ADDR`) — the socket sibling of
//! [`FileTailSource`](crate::stream::file_source::FileTailSource)
//! (ROADMAP streaming follow-on: "socket-tail stream source next to the
//! file tail").
//!
//! The producer speaks the exact `#stream-log v1` line format the file
//! tail reads: one header line, then one line per sample, closing the
//! connection when the capture is complete. Connecting ingests the whole
//! feed up front through the same watermarked late-arrival handling and
//! bucket-spill machinery (`FileTailSource::from_text`), so `gen_chunk`
//! stays pure in the tick and the loader's out-of-order workers stay
//! deterministic — a socket run of a captured feed trains identically to
//! replaying the same capture from a file.
//!
//! A feed is consumed once per connection; `cluster --workers processes`
//! therefore rejects `tcp:` datasets (each worker process would need its
//! own copy of the feed) — capture to a `file:` log for those runs.

use std::io::Read;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use crate::data::Task;
use crate::stream::file_source::FileTailSource;
use crate::stream::source::{StreamChunk, StreamSource};

/// How long a connect / silent feed may take before we give up.
const IO_TIMEOUT: Duration = Duration::from_secs(30);

/// A stream source fed once over TCP (see module docs).
pub struct SocketTailSource {
    inner: FileTailSource,
}

impl SocketTailSource {
    /// Connect to `addr`, read the producer's `#stream-log v1` document
    /// until it closes the connection, and bucket it with the given
    /// late-arrival window.
    pub fn connect(addr: &str, lateness: u64) -> anyhow::Result<SocketTailSource> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| anyhow::anyhow!("cannot connect to stream feed {addr}: {e}"))?;
        stream.set_read_timeout(Some(IO_TIMEOUT)).ok();
        let mut text = String::new();
        let mut reader = std::io::BufReader::new(stream);
        reader
            .read_to_string(&mut text)
            .map_err(|e| anyhow::anyhow!("reading stream feed {addr}: {e}"))?;
        let inner = FileTailSource::from_text(&text, lateness, "tcp")
            .map_err(|e| anyhow::anyhow!("stream feed {addr}: {e}"))?;
        Ok(SocketTailSource { inner })
    }

    /// Records reassigned by the lateness watermark.
    pub fn late_count(&self) -> u64 {
        self.inner.late_count()
    }

    /// Highest effective tick with at least one record.
    pub fn max_tick(&self) -> u64 {
        self.inner.max_tick()
    }

    /// Total records ingested.
    pub fn len(&self) -> usize {
        self.inner.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.is_empty()
    }
}

impl StreamSource for SocketTailSource {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn family(&self) -> &'static str {
        self.inner.family()
    }

    fn task(&self) -> Task {
        self.inner.task()
    }

    fn gen_chunk(&self, tick: u64, max_rows: usize) -> StreamChunk {
        self.inner.gen_chunk(tick, max_rows)
    }

    fn fetch(&self, ids: &[u64], max_rows: usize) -> StreamChunk {
        self.inner.fetch(ids, max_rows)
    }
}

/// Serve one `#stream-log v1` document to the first client that connects
/// — the producer half used by tests and handy for piping captures
/// around: bind an ephemeral listener, return its address, and write the
/// document from a background thread.
pub fn serve_once(
    text: String,
) -> anyhow::Result<(String, std::thread::JoinHandle<std::io::Result<()>>)> {
    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    let handle = std::thread::spawn(move || -> std::io::Result<()> {
        let (mut conn, _) = listener.accept()?;
        std::io::Write::write_all(&mut conn, text.as_bytes())?;
        Ok(())
    });
    Ok((addr, handle))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::XStore;
    use crate::stream::file_source::stream_log_text;
    use crate::stream::source::{build_source, StreamKnobs, ALL_STREAMS};

    fn knobs(seed: u64) -> StreamKnobs {
        StreamKnobs { seed, drift_period: 32, burst_period: 8, burst_min: 0.25 }
    }

    #[test]
    fn round_trips_a_producer_thread_for_every_generator() {
        for name in ALL_STREAMS {
            let gen = build_source(name, knobs(23)).unwrap();
            let text = stream_log_text(gen.as_ref(), 10, 16).unwrap();
            let (addr, producer) = serve_once(text).unwrap();
            let src = SocketTailSource::connect(&addr, 0).unwrap();
            producer.join().unwrap().unwrap();

            assert_eq!(src.name(), "tcp", "{name}");
            assert_eq!(src.family(), gen.family(), "{name}");
            assert_eq!(src.task(), gen.task(), "{name}");
            assert_eq!(src.late_count(), 0, "{name}: in-order feed marked late");
            for tick in 0..10u64 {
                let want = gen.gen_chunk(tick, 16);
                let got = src.gen_chunk(tick, 16);
                assert_eq!(got.ids, want.ids, "{name} tick {tick}");
                match (&got.data.x, &want.data.x) {
                    (XStore::F32 { data: a, .. }, XStore::F32 { data: b, .. }) => {
                        assert_eq!(a, b, "{name} tick {tick}")
                    }
                    (XStore::I32 { data: a, .. }, XStore::I32 { data: b, .. }) => {
                        assert_eq!(a, b, "{name} tick {tick}")
                    }
                    _ => panic!("storage mismatch"),
                }
            }
            // replay fetch works over the socketed feed too
            let c3 = src.gen_chunk(3, 16);
            let got = src.fetch(&[c3.ids[0]], 16);
            assert_eq!(got.ids, vec![c3.ids[0]], "{name}");
        }
    }

    #[test]
    fn socket_feed_honours_the_lateness_watermark() {
        let log = "\
#stream-log v1 family=mlp_bike task=reg feat=2
0 0 1.0,2.0 3.0
1 1 1.5,2.5 3.5
5 2 0.5,0.5 1.0
1 3 9.0,9.0 9.0
";
        let (addr, producer) = serve_once(log.to_string()).unwrap();
        let src = SocketTailSource::connect(&addr, 2).unwrap();
        producer.join().unwrap().unwrap();
        assert_eq!(src.late_count(), 1);
        assert_eq!(src.len(), 4);
        assert_eq!(src.gen_chunk(5, 8).ids, vec![2]);
        assert_eq!(src.gen_chunk(6, 8).ids, vec![3], "late id must spill, not drop");
        assert_eq!(src.max_tick(), 6);
        assert!(!src.is_empty());
    }

    #[test]
    fn bad_feeds_and_dead_endpoints_error() {
        // malformed header
        let (addr, producer) = serve_once("not a stream log\n".to_string()).unwrap();
        assert!(SocketTailSource::connect(&addr, 0).is_err());
        producer.join().unwrap().unwrap();
        // nothing listening (bind an ephemeral port, then drop it)
        let dead = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        assert!(SocketTailSource::connect(&dead, 0).is_err());
        // through the registry spec
        let gen = build_source("drift-reg", knobs(4)).unwrap();
        let text = stream_log_text(gen.as_ref(), 4, 8).unwrap();
        let (addr, producer) = serve_once(text).unwrap();
        let via_registry = build_source(&format!("tcp:{addr}"), knobs(4)).unwrap();
        producer.join().unwrap().unwrap();
        assert_eq!(via_registry.name(), "tcp");
        assert_eq!(via_registry.family(), "mlp_bike");
    }
}

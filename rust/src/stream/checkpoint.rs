//! Checkpoint/resume for the stream trainer.
//!
//! A [`StreamCheckpoint`] captures everything a killed continuous-training
//! run needs to continue *deterministically*: the next tick, the model +
//! optimizer tensors (via `Backend::export_state`), the selection policy's
//! mutable state (method weights, previous per-method losses, iteration
//! counter, and the sampler stream for stochastic baselines), the bounded
//! instance store, and the running selection digest. Stream *sources* are
//! pure functions of the tick, so they need no state here — resuming at
//! tick `t` regenerates identical traffic.
//!
//! Serialization is the crate's own JSON substrate. `u64` values (ids,
//! rng words, digests) are hex strings because JSON numbers are f64 and
//! would truncate them; f32 payloads are exact as f64.
//!
//! Since v4 the store snapshot also records the generational (cur/old)
//! placement inside each shard (`store_old`), so a reloaded store evicts
//! in exactly the saver's order — `--replay` resumes are tick-identical
//! even after the run outgrows its store capacity. v3 checkpoints (no
//! placement) still load; their stores re-age from scratch, which only
//! matters once the resumed run rotates a generation.

use std::path::Path;

use crate::runtime::Tensor;
use crate::selection::policy::Policy;
use crate::stream::store::InstanceRecord;
use crate::util::json::Json;

/// On-disk format version (bump on layout changes).
/// v2: added the drift-detector state and the replay counter.
/// v3: added the forward-scored sample counter, the obftf /
/// selective-backprop policy kinds, and bandit arm ids in the ada
/// snapshot. v2 checkpoints still load (counter defaults to 0, ids to
/// the legacy positional layout, per-method drift detectors to fresh).
/// v4: added the store's old-generation membership (`store_old`) for
/// exact generational placement on resume. v2/v3 checkpoints still load
/// (membership defaults to empty — everything re-ages as current).
const VERSION: f64 = 4.0;
/// Oldest version [`load`] still accepts.
const MIN_VERSION: f64 = 2.0;

/// Everything needed to continue a stream run.
pub struct StreamCheckpoint {
    /// next tick to process (ticks `< tick` are complete)
    pub tick: u64,
    /// model family the tensors belong to
    pub family: String,
    /// `StreamConfig::identity_json` of the run that wrote the checkpoint;
    /// resume rejects a mismatch (different seed/stream/selector would
    /// silently continue over different traffic)
    pub identity: Json,
    /// `Backend::export_state` output
    pub tensors: Vec<Tensor>,
    /// selection-policy state, as produced by [`policy_to_json`]
    pub policy: Json,
    /// live instance-store records
    pub store: Vec<(u64, InstanceRecord)>,
    /// ids of `store` entries that sat in their shard's *old* generation
    /// at save time (v4; empty for v2/v3 checkpoints) — restoring the
    /// placement makes post-resume eviction order exact
    pub store_old: Vec<u64>,
    /// drift-controller state (`DriftGamma::to_json`; `Json::Null` when
    /// drift detection is off)
    pub drift: Json,
    /// running selection-sequence digest up to `tick`
    pub digest: u64,
    pub samples_seen: u64,
    pub samples_trained: u64,
    pub samples_replayed: u64,
    /// rows forward-scored during selection (v2 checkpoints: 0)
    pub samples_forward: u64,
}

fn u64_json(x: u64) -> Json {
    Json::Str(format!("{x:016x}"))
}

fn u64_from(j: &Json) -> anyhow::Result<u64> {
    u64::from_str_radix(j.as_str()?, 16)
        .map_err(|e| anyhow::anyhow!("bad u64 hex in checkpoint: {e}"))
}

/// Serialize the mutable state of a [`Policy`].
pub fn policy_to_json(p: &Policy) -> Json {
    match p {
        Policy::Benchmark(_) => Json::obj(vec![("kind", Json::Str("benchmark".into()))]),
        Policy::Single(s) => Json::obj(vec![
            ("kind", Json::Str("single".into())),
            (
                "rng",
                Json::Arr(s.rng_words().iter().map(|&w| u64_json(w)).collect()),
            ),
        ]),
        Policy::Obftf(o) => Json::obj(vec![
            ("kind", Json::Str("obftf".into())),
            (
                "rng",
                Json::Arr(o.rng_words().iter().map(|&w| u64_json(w)).collect()),
            ),
        ]),
        Policy::SelectiveBackprop(sb) => {
            let (threshold, calls) = sb.threshold_state();
            Json::obj(vec![
                ("kind", Json::Str("selective-backprop".into())),
                (
                    "rng",
                    Json::Arr(sb.rng_words().iter().map(|&w| u64_json(w)).collect()),
                ),
                (
                    "threshold",
                    match threshold {
                        None => Json::Null,
                        Some(t) => Json::from(t as f64),
                    },
                ),
                ("calls", u64_json(calls)),
            ])
        }
        Policy::Ada(a) => {
            let snap = a.state().snapshot();
            Json::obj(vec![
                ("kind", Json::Str("ada".into())),
                ("w", Json::arr_f64(&snap.w.iter().map(|&x| x as f64).collect::<Vec<_>>())),
                (
                    "prev_loss",
                    match &snap.prev_loss {
                        None => Json::Null,
                        Some(v) => {
                            Json::arr_f64(&v.iter().map(|&x| x as f64).collect::<Vec<_>>())
                        }
                    },
                ),
                ("t", Json::from(snap.t)),
                (
                    "ids",
                    match &snap.ids {
                        None => Json::Null,
                        Some(ids) => Json::Arr(
                            ids.iter().map(|id| Json::Str(id.clone())).collect(),
                        ),
                    },
                ),
            ])
        }
    }
}

fn rng_words_from(j: &Json) -> anyhow::Result<[u64; 4]> {
    let words = j.as_arr()?;
    anyhow::ensure!(words.len() == 4, "rng state must be 4 words");
    let mut w = [0u64; 4];
    for (slot, v) in w.iter_mut().zip(words.iter()) {
        *slot = u64_from(v)?;
    }
    Ok(w)
}

/// Restore [`policy_to_json`] state into a freshly-built policy of the
/// same spec (kind mismatch is an error).
pub fn restore_policy(p: &mut Policy, j: &Json) -> anyhow::Result<()> {
    let kind = j.at(&["kind"])?.as_str()?;
    match (p, kind) {
        (Policy::Benchmark(_), "benchmark") => Ok(()),
        (Policy::Single(s), "single") => {
            s.set_rng_words(rng_words_from(j.at(&["rng"])?)?);
            Ok(())
        }
        (Policy::Obftf(o), "obftf") => {
            o.set_rng_words(rng_words_from(j.at(&["rng"])?)?);
            Ok(())
        }
        (Policy::SelectiveBackprop(sb), "selective-backprop") => {
            sb.set_rng_words(rng_words_from(j.at(&["rng"])?)?);
            let threshold = match j.at(&["threshold"])? {
                Json::Null => None,
                v => Some(v.as_f64()? as f32),
            };
            let calls = u64_from(j.at(&["calls"])?)?;
            sb.set_threshold_state(threshold, calls);
            Ok(())
        }
        (Policy::Ada(a), "ada") => {
            let w = j
                .at(&["w"])?
                .as_arr()?
                .iter()
                .map(|v| Ok(v.as_f64()? as f32))
                .collect::<anyhow::Result<Vec<f32>>>()?;
            let prev_loss = match j.at(&["prev_loss"])? {
                Json::Null => None,
                arr => Some(
                    arr.as_arr()?
                        .iter()
                        .map(|v| Ok(v.as_f64()? as f32))
                        .collect::<anyhow::Result<Vec<f32>>>()?,
                ),
            };
            let t = j.at(&["t"])?.as_usize()?;
            // v2 checkpoints carry no "ids": restore positionally
            let ids = match j.at(&["ids"]) {
                Err(_) | Ok(Json::Null) => None,
                Ok(arr) => Some(
                    arr.as_arr()?
                        .iter()
                        .map(|v| Ok(v.as_str()?.to_string()))
                        .collect::<anyhow::Result<Vec<String>>>()?,
                ),
            };
            a.state_mut().restore(crate::selection::AdaSnapshot { w, prev_loss, t, ids })
        }
        (_, other) => anyhow::bail!(
            "checkpoint policy kind '{other}' does not match the configured selector"
        ),
    }
}

fn tensor_to_json(t: &Tensor) -> Json {
    Json::obj(vec![
        (
            "shape",
            Json::Arr(t.shape.iter().map(|&s| Json::from(s)).collect()),
        ),
        (
            "data",
            Json::arr_f64(&t.data.iter().map(|&x| x as f64).collect::<Vec<_>>()),
        ),
    ])
}

fn tensor_from_json(j: &Json) -> anyhow::Result<Tensor> {
    let shape = j.at(&["shape"])?.as_usize_vec()?;
    let data = j
        .at(&["data"])?
        .as_arr()?
        .iter()
        .map(|v| Ok(v.as_f64()? as f32))
        .collect::<anyhow::Result<Vec<f32>>>()?;
    anyhow::ensure!(
        data.len() == shape.iter().product::<usize>(),
        "tensor data/shape mismatch in checkpoint"
    );
    Ok(Tensor { shape, data })
}

fn record_to_json(id: u64, r: &InstanceRecord) -> Json {
    Json::Arr(vec![
        u64_json(id),
        Json::from(r.loss as f64),
        Json::from(r.gnorm as f64),
        Json::from(r.last_tick as usize),
        Json::from(r.visits as usize),
    ])
}

fn record_from_json(j: &Json) -> anyhow::Result<(u64, InstanceRecord)> {
    let a = j.as_arr()?;
    anyhow::ensure!(a.len() == 5, "store record must have 5 fields");
    Ok((
        u64_from(&a[0])?,
        InstanceRecord {
            loss: a[1].as_f64()? as f32,
            gnorm: a[2].as_f64()? as f32,
            last_tick: a[3].as_usize()? as u32,
            visits: a[4].as_usize()? as u32,
        },
    ))
}

/// Write a checkpoint atomically (temp file + rename) so a crash mid-save
/// never corrupts the previous checkpoint.
pub fn save(path: &Path, ck: &StreamCheckpoint) -> anyhow::Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let j = Json::obj(vec![
        ("version", Json::Num(VERSION)),
        ("tick", u64_json(ck.tick)),
        ("family", Json::Str(ck.family.clone())),
        ("identity", ck.identity.clone()),
        ("tensors", Json::Arr(ck.tensors.iter().map(tensor_to_json).collect())),
        ("policy", ck.policy.clone()),
        (
            "store",
            Json::Arr(ck.store.iter().map(|(id, r)| record_to_json(*id, r)).collect()),
        ),
        (
            "store_old",
            Json::Arr(ck.store_old.iter().map(|&id| u64_json(id)).collect()),
        ),
        ("drift", ck.drift.clone()),
        ("digest", u64_json(ck.digest)),
        ("samples_seen", u64_json(ck.samples_seen)),
        ("samples_trained", u64_json(ck.samples_trained)),
        ("samples_replayed", u64_json(ck.samples_replayed)),
        ("samples_forward", u64_json(ck.samples_forward)),
    ]);
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, j.to_string())?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Checkpoints written before `--drift-detect` grew detector names store
/// the identity's `drift-detect` as a boolean; map it onto today's
/// selector strings (`true` could only mean the then-only Page–Hinkley
/// detector) so those runs stay resumable. Likewise, checkpoints from
/// before `--obftf-k` existed lack the key — fill in its default so the
/// identity check passes for runs that could not have used it.
fn normalize_identity(mut identity: Json) -> Json {
    if let Json::Obj(m) = &mut identity {
        if let Some(Json::Bool(b)) = m.get("drift-detect") {
            let s = if *b { "page-hinkley" } else { "off" };
            m.insert("drift-detect".into(), Json::Str(s.into()));
        }
        if !m.contains_key("obftf-k") {
            m.insert("obftf-k".into(), Json::from(10usize));
        }
    }
    identity
}

/// Load a checkpoint written by [`save`].
pub fn load(path: &Path) -> anyhow::Result<StreamCheckpoint> {
    let text = std::fs::read_to_string(path)?;
    let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;
    let version = j.at(&["version"])?.as_f64()?;
    anyhow::ensure!(
        (MIN_VERSION..=VERSION).contains(&version),
        "checkpoint version {version} unsupported (expected {MIN_VERSION}..={VERSION})"
    );
    Ok(StreamCheckpoint {
        tick: u64_from(j.at(&["tick"])?)?,
        family: j.at(&["family"])?.as_str()?.to_string(),
        identity: normalize_identity(j.at(&["identity"])?.clone()),
        tensors: j
            .at(&["tensors"])?
            .as_arr()?
            .iter()
            .map(tensor_from_json)
            .collect::<anyhow::Result<Vec<_>>>()?,
        policy: j.at(&["policy"])?.clone(),
        store: j
            .at(&["store"])?
            .as_arr()?
            .iter()
            .map(record_from_json)
            .collect::<anyhow::Result<Vec<_>>>()?,
        // absent in v2/v3 checkpoints — the store re-ages as all-current
        store_old: match j.at(&["store_old"]) {
            Ok(arr) => arr
                .as_arr()?
                .iter()
                .map(u64_from)
                .collect::<anyhow::Result<Vec<_>>>()?,
            Err(_) => Vec::new(),
        },
        drift: j.at(&["drift"])?.clone(),
        digest: u64_from(j.at(&["digest"])?)?,
        samples_seen: u64_from(j.at(&["samples_seen"])?)?,
        samples_trained: u64_from(j.at(&["samples_trained"])?)?,
        samples_replayed: u64_from(j.at(&["samples_replayed"])?)?,
        // absent in v2 checkpoints
        samples_forward: match j.at(&["samples_forward"]) {
            Ok(v) => u64_from(v)?,
            Err(_) => 0,
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selection::policy::build_policy;
    use crate::selection::SelectionContext;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("ada_ck_{name}_{}.json", std::process::id()))
    }

    #[test]
    fn checkpoint_file_round_trips() {
        let mut policy = build_policy("adaselection", 1, 0.5, true, -0.5).unwrap();
        // advance the policy so there is real state to carry
        let loss: Vec<f32> = (0..16).map(|i| 0.1 + i as f32 * 0.2).collect();
        let gnorm = vec![1.0f32; 16];
        for _ in 0..3 {
            policy.select(&SelectionContext { loss: &loss, gnorm: &gnorm, k: 4, history: None });
        }
        let ck = StreamCheckpoint {
            tick: 0xdead_beef_0000_0042,
            family: "stream_class".into(),
            identity: crate::config::StreamConfig::default().identity_json(),
            tensors: vec![Tensor { shape: vec![2, 3], data: vec![0.5, -1.25, 3.0, 0.0, 7.5, -0.125] }],
            policy: policy_to_json(&policy),
            store: vec![
                (u64::MAX, InstanceRecord { loss: 1.5, gnorm: 0.25, last_tick: 9, visits: 3 }),
                (0, InstanceRecord { loss: 0.0, gnorm: 0.0, last_tick: 0, visits: 1 }),
            ],
            store_old: vec![0, u64::MAX],
            drift: crate::stream::tick::DriftGamma::default().to_json(),
            digest: u64::MAX - 7,
            samples_seen: 1 << 60,
            samples_trained: 12345,
            samples_replayed: 678,
            samples_forward: 90123,
        };
        let path = tmp("round_trip");
        save(&path, &ck).unwrap();
        let back = load(&path).unwrap();
        std::fs::remove_file(&path).ok();

        assert_eq!(back.tick, ck.tick);
        assert_eq!(back.family, ck.family);
        assert_eq!(back.identity, ck.identity);
        assert_eq!(back.tensors.len(), 1);
        assert_eq!(back.tensors[0].shape, vec![2, 3]);
        assert_eq!(back.tensors[0].data, ck.tensors[0].data);
        assert_eq!(back.store, ck.store);
        assert_eq!(back.store_old, ck.store_old);
        assert_eq!(back.drift, ck.drift);
        assert_eq!(back.digest, ck.digest);
        assert_eq!(back.samples_seen, ck.samples_seen);
        assert_eq!(back.samples_trained, ck.samples_trained);
        assert_eq!(back.samples_replayed, ck.samples_replayed);
        assert_eq!(back.samples_forward, ck.samples_forward);

        // policy state restores into an identically-specced policy
        let mut fresh = build_policy("adaselection", 1, 0.5, true, -0.5).unwrap();
        restore_policy(&mut fresh, &back.policy).unwrap();
        assert_eq!(fresh.weights(), policy.weights());
        let a = policy.select(&SelectionContext { loss: &loss, gnorm: &gnorm, k: 4, history: None });
        let b = fresh.select(&SelectionContext { loss: &loss, gnorm: &gnorm, k: 4, history: None });
        assert_eq!(a, b);
    }

    #[test]
    fn single_method_rng_resumes() {
        let loss: Vec<f32> = (0..32).map(|i| i as f32).collect();
        let gnorm = vec![1.0f32; 32];
        let mut p = build_policy("uniform", 9, 0.5, true, -0.5).unwrap();
        for _ in 0..5 {
            p.select(&SelectionContext { loss: &loss, gnorm: &gnorm, k: 8, history: None });
        }
        let saved = policy_to_json(&p);
        let expect = p.select(&SelectionContext { loss: &loss, gnorm: &gnorm, k: 8, history: None });

        let mut q = build_policy("uniform", 9, 0.5, true, -0.5).unwrap();
        restore_policy(&mut q, &saved).unwrap();
        let got = q.select(&SelectionContext { loss: &loss, gnorm: &gnorm, k: 8, history: None });
        assert_eq!(expect, got);
    }

    #[test]
    fn forward_cheap_policy_state_round_trips() {
        let loss: Vec<f32> = (0..32).map(|i| (i * 7 % 13) as f32).collect();
        let gnorm = vec![1.0f32; 32];
        let ctx = |k| SelectionContext { loss: &loss, gnorm: &gnorm, k, history: None };

        // obftf: rng words carry across save/restore
        let mut p = build_policy("obftf", 3, 0.5, true, -0.5).unwrap();
        p.plan(256, 8); // advance the candidate-plan rng
        let saved = policy_to_json(&p);
        let expect_plan = p.plan(256, 8).candidate_rows;
        let mut q = build_policy("obftf", 3, 0.5, true, -0.5).unwrap();
        restore_policy(&mut q, &saved).unwrap();
        assert_eq!(q.plan(256, 8).candidate_rows, expect_plan);

        // selective-backprop: threshold + call counter + fill rng carry
        let mut p = build_policy("selective-backprop", 3, 0.5, true, -0.5).unwrap();
        p.select(&ctx(8));
        let saved = policy_to_json(&p);
        let expect = p.select(&ctx(8));
        let mut q = build_policy("selective-backprop", 3, 0.5, true, -0.5).unwrap();
        restore_policy(&mut q, &saved).unwrap();
        assert_eq!(q.select(&ctx(8)), expect);

        // kind mismatch between the two new kinds is rejected
        let mut o = build_policy("obftf", 3, 0.5, true, -0.5).unwrap();
        assert!(restore_policy(&mut o, &saved).is_err());
    }

    #[test]
    fn v2_checkpoint_without_forward_counter_or_ids_loads() {
        // simulate a v2-era file: version 2.0, no samples_forward key,
        // ada policy without "ids"
        let mut policy = build_policy("adaselection", 1, 0.5, true, -0.5).unwrap();
        let loss: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let gnorm = vec![1.0f32; 16];
        policy.select(&SelectionContext { loss: &loss, gnorm: &gnorm, k: 4, history: None });
        let mut pj = match policy_to_json(&policy) {
            Json::Obj(m) => m,
            _ => unreachable!(),
        };
        pj.remove("ids");
        let ck = StreamCheckpoint {
            tick: 7,
            family: "stream_class".into(),
            identity: crate::config::StreamConfig::default().identity_json(),
            tensors: Vec::new(),
            policy: Json::Obj(pj),
            store: Vec::new(),
            store_old: Vec::new(),
            drift: Json::Null,
            digest: 0,
            samples_seen: 10,
            samples_trained: 4,
            samples_replayed: 0,
            samples_forward: 999, // will be dropped from the v2 payload below
        };
        let path = tmp("v2_compat");
        save(&path, &ck).unwrap();
        // rewrite as v2: drop the new key, stamp the old version
        let text = std::fs::read_to_string(&path).unwrap();
        let mut j = match Json::parse(&text).unwrap() {
            Json::Obj(m) => m,
            _ => unreachable!(),
        };
        j.remove("samples_forward");
        j.remove("store_old");
        j.insert("version".into(), Json::Num(2.0));
        std::fs::write(&path, Json::Obj(j).to_string()).unwrap();

        let back = load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back.samples_forward, 0, "v2 load must default the counter");
        assert!(back.store_old.is_empty(), "v2 load must default the placement");

        // the id-less ada payload restores positionally into the same spec
        let mut fresh = build_policy("adaselection", 1, 0.5, true, -0.5).unwrap();
        restore_policy(&mut fresh, &back.policy).unwrap();
        assert_eq!(fresh.weights(), policy.weights());
    }

    #[test]
    fn kind_mismatch_is_rejected() {
        let p = build_policy("uniform", 0, 0.5, true, -0.5).unwrap();
        let saved = policy_to_json(&p);
        let mut ada = build_policy("adaselection", 0, 0.5, true, -0.5).unwrap();
        assert!(restore_policy(&mut ada, &saved).is_err());
    }

    #[test]
    fn legacy_boolean_drift_detect_identity_still_resumes() {
        // checkpoints from before detector selection stored the identity's
        // drift-detect as a boolean; loading must map it to the selector
        // string today's identity_json emits
        let mut cfg = crate::config::StreamConfig::default();
        cfg.drift_detect = "off".into();
        let mut legacy = match cfg.identity_json() {
            Json::Obj(m) => m,
            _ => unreachable!(),
        };
        legacy.insert("drift-detect".into(), Json::Bool(false));
        let ck = StreamCheckpoint {
            tick: 1,
            family: "stream_class".into(),
            identity: Json::Obj(legacy),
            tensors: Vec::new(),
            policy: policy_to_json(&build_policy("uniform", 0, 0.5, true, -0.5).unwrap()),
            store: Vec::new(),
            store_old: Vec::new(),
            drift: Json::Null,
            digest: 0,
            samples_seen: 0,
            samples_trained: 0,
            samples_replayed: 0,
            samples_forward: 0,
        };
        let path = tmp("legacy_identity");
        save(&path, &ck).unwrap();
        let back = load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back.identity, cfg.identity_json(), "legacy bool not normalized");

        // and the page-hinkley half of the mapping
        cfg.drift_detect = "page-hinkley".into();
        let mut legacy = match cfg.identity_json() {
            Json::Obj(m) => m,
            _ => unreachable!(),
        };
        legacy.insert("drift-detect".into(), Json::Bool(true));
        assert_eq!(
            super::normalize_identity(Json::Obj(legacy)),
            cfg.identity_json()
        );
    }
}

//! One training run end to end (Algorithm 2 of the paper), generic over the
//! compute [`Backend`].
//!
//! Per iteration:
//!   1. the pipeline delivers a full batch `B_t` (prefetched, backpressured);
//!   2. a cheap forward pass produces per-sample (loss, gnorm);
//!   3. the policy picks the top ⌈γB⌉ rows — AdaSelection scores on the
//!      backend scorer (`kernel_scorer`: the L1 Pallas kernel on XLA, the
//!      same math inline on the native backend) or the host oracle;
//!   4. a train step sized to that subset runs SGD+momentum on the selected
//!      rows (the XLA backend rounds to a compiled size; native trains the
//!      exact ⌈γB⌉).
//!
//! The benchmark policy skips 2–3 and trains on the full batch, which is
//! how the paper's "training time" comparison is produced: method time =
//! fwd(B) + train(⌈γB⌉) vs benchmark time = train(B).

use crate::config::RunConfig;
use crate::data::{self, Dataset};
use crate::metrics::{EpochStats, RunResult};
use crate::obs;
use crate::pipeline::{gather, Batch, Loader, LoaderConfig};
use crate::runtime::{Backend, FamilyMeta, NativeBackend};
use crate::selection::policy::Policy;
use crate::selection::{LossCache, ScoringNeeds, SelectionContext};

use super::earlystop::EarlyStop;
use crate::util::stats::Welford;
use crate::util::timer::{PhaseTimer, Stopwatch};

/// A trainer borrowing a backend for one run.
pub struct Trainer<'b, B: Backend> {
    pub backend: &'b mut B,
    pub cfg: RunConfig,
    train_ds: Dataset,
    test_ds: Dataset,
    family: String,
    meta: FamilyMeta,
}

impl<'b, B: Backend> Trainer<'b, B> {
    pub fn new(backend: &'b mut B, cfg: RunConfig) -> anyhow::Result<Trainer<'b, B>> {
        cfg.validate()?;
        backend.validate()?;
        let family = data::family_for(&cfg.dataset)?.to_string();
        let meta = backend.family_meta(&family)?;
        let split = data::build(&cfg.dataset, cfg.seed, cfg.data_scale)?;
        split.train.validate()?;
        split.test.validate()?;
        Ok(Trainer {
            backend,
            cfg,
            train_ds: split.train,
            test_ds: split.test,
            family,
            meta,
        })
    }

    /// The train-step subset size for this run's γ: exactly ⌈γB⌉ on
    /// backends without a compiled-size grid, else the next compiled size.
    pub fn subset_size(&self) -> usize {
        let target = (self.cfg.gamma * self.meta.batch as f64).ceil() as usize;
        self.meta.round_size(target.max(1))
    }

    /// Run the configured training job.
    pub fn run(&mut self) -> anyhow::Result<RunResult> {
        let b = self.meta.batch;
        let k = self.subset_size();
        let mut policy = Policy::from_run_config(&self.cfg)?;
        // §5 future-work: stale-loss forward approximation + early stopping.
        // The cache is a shim over the same sharded InstanceStore the
        // stream trainer uses (one statistics store for both trainers).
        let mut cache = LossCache::new(self.train_ds.len(), self.cfg.stale_refresh);
        let mut early = self
            .cfg
            .early_stop
            .then(|| EarlyStop::new(self.cfg.patience, 0.01, 0.02));
        // keep compilation out of the timed loop (no-op natively). The
        // batch trainer always forward-scores the full batch (candidate
        // planning is a stream-path optimization), so every non-benchmark
        // policy needs the same {k, b} sizes here.
        let sizes: Vec<usize> =
            if policy.scoring() == ScoringNeeds::None { vec![b] } else { vec![k, b] };
        self.backend.preload_family(&self.family, &sizes)?;

        // registry handles resolved once; per-iteration cost is an atomic
        // store. The batch trainer shares the arm-weight and phase series
        // with the stream path so one dashboard covers both.
        let reg = obs::registry();
        let iter_counter = reg.counter("adaselection_train_iterations_total");
        let epoch_gauge = reg.gauge("adaselection_train_epoch");
        let test_loss_gauge = reg.gauge("adaselection_train_test_loss");
        let test_acc_gauge = reg.gauge("adaselection_train_test_acc");
        let arm_gauges: Vec<_> = policy
            .weight_ids()
            .iter()
            .map(|id| {
                reg.gauge(&obs::series("adaselection_arm_weight", &[("arm", id.as_str())]))
            })
            .collect();

        let mut state = self.backend.init_state(&self.family, self.cfg.seed as i32)?;
        let mut phases = PhaseTimer::default();
        let mut epochs: Vec<EpochStats> = Vec::new();
        let mut weight_trace: Vec<Vec<f32>> = Vec::new();
        let mut iterations = 0usize;
        let mut train_clock = 0.0f64; // training time excluding eval
        // Alg-2 accumulate mode: selected rows buffered until |C| = B
        let mut acc_buf: Option<Batch> = None;

        log::info!(
            "run start: backend={} dataset={} selector={} γ={} k={}/{} epochs={} train={} test={}",
            self.backend.name(),
            self.cfg.dataset,
            policy.name(),
            self.cfg.gamma,
            k,
            b,
            self.cfg.epochs,
            self.train_ds.len(),
            self.test_ds.len()
        );

        for epoch in 0..self.cfg.epochs {
            let loader_cfg = LoaderConfig {
                batch_size: b,
                epochs: 1,
                seed: self.cfg.seed.wrapping_add(epoch as u64),
                workers: self.cfg.workers,
                capacity: self.cfg.capacity,
                drop_last: true,
            };
            let mut loader = Loader::start(self.train_ds.clone(), &loader_cfg);
            let mut train_loss = Welford::default();
            let epoch_clock = Stopwatch::new();

            loop {
                let batch = {
                    let t0 = std::time::Instant::now();
                    let batch = loader.next_batch();
                    phases.add("data", t0.elapsed());
                    match batch {
                        Some(batch) => batch,
                        None => break,
                    }
                };
                iterations += 1;
                iter_counter.inc();

                if policy.scoring() == ScoringNeeds::None {
                    let loss = phases.time("update", || {
                        self.backend.train_step(&mut state, &batch, self.cfg.lr)
                    })?;
                    train_loss.push(loss as f64);
                    continue;
                }

                let real = &batch.indices[..batch.real];
                // Selection path, fastest applicable first:
                //   1. stale-loss cache hit — no forward pass at all;
                //   2. fused fwd+score pass (AdaSelection on the backend
                //      scorer) — one backend call;
                //   3. separate forward then score/host policy.
                let selected = if cache.can_skip_forward(real, epoch) {
                    let (loss, gnorm) =
                        phases.time("cache", || Ok::<_, anyhow::Error>(cache.lookup(real)))?;
                    let t0 = std::time::Instant::now();
                    let sel = self.select(&mut policy, &loss, &gnorm, k)?;
                    phases.add("select", t0.elapsed());
                    sel
                } else {
                    // the fused kernel path needs the frozen 7-row α layout,
                    // so it only applies to all-kernel bandit pools
                    // (`kernel_weights` is None once a forward-cheap arm
                    // like obftf joins)
                    let fused = if self.cfg.kernel_scorer {
                        match policy.as_ada().and_then(|ada| {
                            ada.state().kernel_weights().map(|w| {
                                let t_next = ada.state().iteration() + 1;
                                let c = ada.state().config();
                                (w, t_next, c.cl_on, c.cl_power)
                            })
                        }) {
                            Some((w_full, t_next, cl_on, cl_power)) => {
                                phases.time("forward", || {
                                    self.backend.forward_score_fused(
                                        &state, &batch, &w_full, t_next, cl_power, cl_on,
                                    )
                                })?
                            }
                            None => None,
                        }
                    } else {
                        None
                    };
                    match fused {
                        Some(f) => {
                            let real_n = batch.real;
                            cache.update(real, &f.loss[..real_n], &f.gnorm[..real_n], epoch);
                            let t0 = std::time::Instant::now();
                            let ada = policy.as_ada().expect("fused path is ada-only");
                            let sel = ada.select_kernel(&f.loss, &f.alphas, f.scores, k);
                            phases.add("select", t0.elapsed());
                            sel
                        }
                        None => {
                            let (loss, gnorm) = phases
                                .time("forward", || self.backend.forward_scores(&state, &batch))?;
                            cache.update(real, &loss[..batch.real], &gnorm[..batch.real], epoch);
                            let t0 = std::time::Instant::now();
                            let sel = self.select(&mut policy, &loss, &gnorm, k)?;
                            phases.add("select", t0.elapsed());
                            sel
                        }
                    }
                };
                if let Some(w) = policy.weights() {
                    if let Some(es) = early.as_mut() {
                        es.observe_weights(&w);
                    }
                    for (g, &v) in arm_gauges.iter().zip(&w) {
                        if v.is_finite() {
                            g.set(v as f64);
                        }
                    }
                    weight_trace.push(w);
                }

                let sub = batch.gather_rows(&selected);
                if self.cfg.accumulate {
                    // Alg 2 lines 8–11: pool selections, update on full batches
                    let pool = match acc_buf.take() {
                        None => sub,
                        Some(prev) => concat_batches(&prev, &sub),
                    };
                    if pool.len() >= b {
                        let rows: Vec<usize> = (0..b).collect();
                        let full = pool.gather_rows(&rows);
                        let loss = phases.time("update", || {
                            self.backend.train_step(&mut state, &full, self.cfg.lr)
                        })?;
                        train_loss.push(loss as f64);
                        let rest: Vec<usize> = (b..pool.len()).collect();
                        acc_buf = (!rest.is_empty()).then(|| pool.gather_rows(&rest));
                    } else {
                        acc_buf = Some(pool);
                    }
                } else {
                    let loss = phases
                        .time("update", || self.backend.train_step(&mut state, &sub, self.cfg.lr))?;
                    train_loss.push(loss as f64);
                }
            }

            train_clock += epoch_clock.elapsed_secs();
            let (test_loss, test_acc) =
                phases.time("eval", || self.evaluate(&state))?;
            epoch_gauge.set(epoch as f64);
            test_loss_gauge.set(test_loss as f64);
            if test_acc.is_finite() {
                test_acc_gauge.set(test_acc as f64);
            }
            log::info!(
                "epoch {epoch}: train_loss={:.4} test_loss={test_loss:.4} \
                 test_acc={test_acc:.4} ({:.1}s train)",
                train_loss.mean(),
                train_clock
            );
            epochs.push(EpochStats {
                epoch,
                train_loss: train_loss.mean() as f32,
                test_loss,
                test_acc,
                train_time_s: train_clock,
            });
            if let Some(es) = early.as_mut() {
                if es.observe_epoch(test_loss as f64) {
                    log::info!("early stop at epoch {epoch} (AdaSelection indicator)");
                    break;
                }
            }
        }
        if self.cfg.stale_refresh > 0 {
            let (hits, misses) = cache.stats();
            log::info!(
                "stale-loss cache: {hits} cache-served / {misses} forward batches ({:.0}% hit), \
                 store {} records / {} B",
                100.0 * cache.hit_rate(),
                cache.store().len(),
                cache.store().approx_bytes()
            );
        }

        // publish cumulative per-phase seconds so `/metrics` carries the
        // same profile the CSV summaries print
        for (name, d) in phases.phases() {
            reg.gauge(&obs::series("adaselection_phase_seconds", &[("phase", name)]))
                .set(d.as_secs_f64());
        }

        Ok(RunResult {
            dataset: self.cfg.dataset.clone(),
            selector: policy.name(),
            gamma: self.cfg.gamma,
            beta: self.cfg.beta,
            seed: self.cfg.seed,
            epochs,
            weight_trace,
            weight_names: policy.weight_ids(),
            phases,
            iterations,
        })
    }

    fn select(
        &mut self,
        policy: &mut Policy,
        loss: &[f32],
        gnorm: &[f32],
        k: usize,
    ) -> anyhow::Result<Vec<usize>> {
        if self.cfg.kernel_scorer {
            if let Some(ada) = policy.as_ada() {
                // backend scorer (the L1 Pallas kernel on XLA, same math
                // natively): fused α + s computed off-policy. Pools with a
                // non-kernel arm fall through to the host path below.
                if let Some(w_full) = ada.state().kernel_weights() {
                    let t_next = ada.state().iteration() + 1;
                    let (cl_on, cl_power) = {
                        let c = ada.state().config();
                        (c.cl_on, c.cl_power)
                    };
                    let (scores, alphas) =
                        self.backend
                            .score(loss, gnorm, &w_full, t_next, cl_power, cl_on)?;
                    return Ok(ada.select_kernel(loss, &alphas, scores, k));
                }
            }
        }
        Ok(policy.select(&SelectionContext { loss, gnorm, k, history: None }))
    }

    /// Full test-set evaluation: (mean loss, accuracy | NaN).
    pub fn evaluate(&mut self, state: &B::State) -> anyhow::Result<(f32, f32)> {
        let b = self.meta.batch;
        let n = self.test_ds.len();
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut count = 0usize;
        let mut start = 0usize;
        while start < n {
            let end = (start + b).min(n);
            let idx: Vec<usize> = (start..end).collect();
            let batch = gather(&self.test_ds, &idx, b, 0, 0);
            let (ls, cs) = self.backend.eval(state, &batch)?;
            loss_sum += ls as f64;
            correct += cs as f64;
            count += end - start;
            start = end;
        }
        let mean_loss = (loss_sum / count.max(1) as f64) as f32;
        let acc = match self.meta.task {
            crate::runtime::TaskKind::Regression => f32::NAN,
            _ => (correct / count.max(1) as f64) as f32,
        };
        Ok((mean_loss, acc))
    }
}

/// Concatenate two dense sub-batches (accumulate mode).
fn concat_batches(a: &Batch, bb: &Batch) -> Batch {
    fn cat<T: Clone>(x: &Option<Vec<T>>, y: &Option<Vec<T>>) -> Option<Vec<T>> {
        match (x, y) {
            (Some(x), Some(y)) => {
                let mut v = x.clone();
                v.extend_from_slice(y);
                Some(v)
            }
            (None, None) => None,
            _ => panic!("batch storage mismatch in concat"),
        }
    }
    let mut indices = a.indices.clone();
    indices.extend_from_slice(&bb.indices);
    Batch {
        epoch: bb.epoch,
        index_in_epoch: bb.index_in_epoch,
        real: a.real + bb.real,
        indices,
        x_f32: cat(&a.x_f32, &bb.x_f32),
        x_i32: cat(&a.x_i32, &bb.x_i32),
        y_f32: cat(&a.y_f32, &bb.y_f32),
        y_i32: cat(&a.y_i32, &bb.y_i32),
    }
}

/// Convenience: run one job on a fresh backend picked by `cfg.backend`.
pub fn run(cfg: RunConfig) -> anyhow::Result<RunResult> {
    match cfg.backend.as_str() {
        "native" => {
            let mut backend = NativeBackend::new();
            Trainer::new(&mut backend, cfg)?.run()
        }
        "xla" => run_xla(cfg),
        other => anyhow::bail!("unknown backend '{other}' (expected native|xla)"),
    }
}

#[cfg(feature = "xla")]
fn run_xla(cfg: RunConfig) -> anyhow::Result<RunResult> {
    let mut engine = crate::runtime::Engine::new(&cfg.artifacts_dir)?;
    Trainer::new(&mut engine, cfg)?.run()
}

#[cfg(not(feature = "xla"))]
fn run_xla(_cfg: RunConfig) -> anyhow::Result<RunResult> {
    anyhow::bail!("backend 'xla' requires building with `--features xla`")
}

/// Run one job on a shared backend (sweeps reuse compiled executables on
/// XLA; natively this just avoids re-allocating the family table).
pub fn run_with<B: Backend>(backend: &mut B, cfg: RunConfig) -> anyhow::Result<RunResult> {
    Trainer::new(backend, cfg)?.run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Task, XStore, YStore};

    fn dense(vals: &[(f32, f32)]) -> Batch {
        Batch {
            epoch: 0,
            index_in_epoch: 0,
            indices: (0..vals.len()).collect(),
            real: vals.len(),
            x_f32: Some(vals.iter().map(|v| v.0).collect()),
            x_i32: None,
            y_f32: Some(vals.iter().map(|v| v.1).collect()),
            y_i32: None,
        }
    }

    #[test]
    fn concat_preserves_order_and_counts() {
        let a = dense(&[(1.0, 10.0), (2.0, 20.0)]);
        let b = dense(&[(3.0, 30.0)]);
        let c = concat_batches(&a, &b);
        assert_eq!(c.real, 3);
        assert_eq!(c.x_f32.as_ref().unwrap(), &vec![1.0, 2.0, 3.0]);
        assert_eq!(c.y_f32.as_ref().unwrap(), &vec![10.0, 20.0, 30.0]);
    }

    #[test]
    fn trainer_rejects_invalid_config() {
        let mut cfg = RunConfig::default();
        cfg.gamma = 0.0;
        let mut backend = NativeBackend::new();
        assert!(Trainer::new(&mut backend, cfg).is_err());
    }

    #[test]
    fn unknown_backend_errors() {
        let mut cfg = RunConfig::default();
        cfg.backend = "tpu9000".into();
        assert!(cfg.validate().is_err());
        assert!(run(cfg).is_err());
    }

    // validate storage-kind assertions on helper
    #[test]
    #[should_panic]
    fn concat_mismatched_storage_panics() {
        let a = dense(&[(1.0, 1.0)]);
        let mut b = dense(&[(2.0, 2.0)]);
        b.x_f32 = None;
        b.x_i32 = Some(vec![1]);
        let _ = concat_batches(&a, &b);
    }

    #[test]
    fn datasets_for_all_tasks_assemble() {
        // smoke: feature storage kinds line up with tasks (backend-free)
        for name in crate::data::ALL_DATASETS {
            let split = crate::data::build(name, 1, 0.01).unwrap();
            let idx: Vec<usize> = (0..4.min(split.train.len())).collect();
            let b = gather(&split.train, &idx, 4, 0, 0);
            match split.train.task {
                Task::Lm { .. } => assert!(b.x_i32.is_some()),
                _ => assert!(b.x_f32.is_some()),
            }
            match (&split.train.task, &split.train.y) {
                (Task::Regression, YStore::F32(_)) => assert!(b.y_f32.is_some()),
                (Task::Classification { .. }, YStore::I32(_)) => assert!(b.y_i32.is_some()),
                (Task::Lm { .. }, YStore::Seq { .. }) => assert!(b.y_i32.is_some()),
                other => panic!("mismatch {other:?}"),
            }
            match &split.train.x {
                XStore::F32 { .. } => assert!(b.x_f32.is_some()),
                XStore::I32 { .. } => assert!(b.x_i32.is_some()),
            }
        }
    }
}

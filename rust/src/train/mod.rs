//! The training coordinator: wires pipeline → forward artifact → selection
//! policy → train-step artifact, with per-phase time accounting (the basis
//! of the paper's Fig-3 training-time comparison) and per-epoch evaluation.

pub mod earlystop;
pub mod trainer;

pub use trainer::{run, run_with, Trainer};

//! Early-stopping indicator built on AdaSelection's internal signals — the
//! paper's §5 future-work item ("using it as an indicator for stopping the
//! learning process").
//!
//! Two signals must agree before stopping:
//!   1. **weight stability** — the method weights w_t^m have stopped
//!      moving (max per-iteration delta below `w_tol` across the window):
//!      the policy has converged on a strategy, and
//!   2. **loss plateau** — the per-epoch test loss improved by less than
//!      `rel_tol` (relative) over the last `patience` epochs.

/// Early-stop state machine (feed per-iteration weights + per-epoch losses).
#[derive(Clone, Debug)]
pub struct EarlyStop {
    pub patience: usize,
    pub rel_tol: f64,
    pub w_tol: f32,
    losses: Vec<f64>,
    last_w: Option<Vec<f32>>,
    max_w_delta_this_epoch: f32,
    w_stable_epochs: usize,
}

impl EarlyStop {
    pub fn new(patience: usize, rel_tol: f64, w_tol: f32) -> Self {
        EarlyStop {
            patience: patience.max(1),
            rel_tol,
            w_tol,
            losses: Vec::new(),
            last_w: None,
            max_w_delta_this_epoch: 0.0,
            w_stable_epochs: 0,
        }
    }

    /// Observe the policy weights after one iteration.
    pub fn observe_weights(&mut self, w: &[f32]) {
        if let Some(prev) = &self.last_w {
            let delta = prev
                .iter()
                .zip(w)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            self.max_w_delta_this_epoch = self.max_w_delta_this_epoch.max(delta);
        }
        self.last_w = Some(w.to_vec());
    }

    /// Observe the end-of-epoch test loss; returns `true` to stop.
    pub fn observe_epoch(&mut self, test_loss: f64) -> bool {
        // weight stability bookkeeping
        if self.last_w.is_some() {
            if self.max_w_delta_this_epoch <= self.w_tol {
                self.w_stable_epochs += 1;
            } else {
                self.w_stable_epochs = 0;
            }
        } else {
            // non-AdaSelection runs: weights trivially "stable"
            self.w_stable_epochs += 1;
        }
        self.max_w_delta_this_epoch = 0.0;
        self.losses.push(test_loss);

        if self.losses.len() <= self.patience {
            return false;
        }
        let now = *self.losses.last().unwrap();
        let before = self.losses[self.losses.len() - 1 - self.patience];
        let improved = (before - now) / before.abs().max(1e-12);
        improved < self.rel_tol && self.w_stable_epochs >= self.patience
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn does_not_stop_while_improving() {
        let mut es = EarlyStop::new(2, 0.01, 0.05);
        for (i, &l) in [10.0, 8.0, 6.0, 4.5, 3.4].iter().enumerate() {
            assert!(!es.observe_epoch(l), "stopped at epoch {i}");
        }
    }

    #[test]
    fn stops_on_plateau_with_stable_weights() {
        let mut es = EarlyStop::new(2, 0.01, 0.05);
        let mut stopped = false;
        for &l in &[10.0, 5.0, 3.0, 3.0, 2.999, 2.999, 2.998] {
            es.observe_weights(&[1.0, 1.0]);
            es.observe_weights(&[1.0, 1.0]);
            if es.observe_epoch(l) {
                stopped = true;
                break;
            }
        }
        assert!(stopped);
    }

    #[test]
    fn unstable_weights_defer_stop() {
        let mut es = EarlyStop::new(2, 0.01, 0.01);
        let mut alt = 0.0f32;
        for &l in &[3.0, 3.0, 3.0, 3.0, 3.0] {
            // weights keep oscillating beyond tolerance
            es.observe_weights(&[1.0 + alt, 1.0 - alt]);
            alt = if alt == 0.0 { 0.5 } else { 0.0 };
            es.observe_weights(&[1.0 + alt, 1.0 - alt]);
            assert!(!es.observe_epoch(l));
        }
    }

    #[test]
    fn plateau_without_weight_signal_still_stops() {
        // single-method runs never call observe_weights
        let mut es = EarlyStop::new(2, 0.01, 0.05);
        let mut stopped = false;
        for &l in &[5.0, 5.0, 5.0, 5.0] {
            if es.observe_epoch(l) {
                stopped = true;
                break;
            }
        }
        assert!(stopped);
    }

    #[test]
    fn patience_zero_clamps_to_one() {
        // patience = 0 must behave as patience = 1, not stop instantly on
        // the very first epoch (the `losses.len() <= patience` guard needs
        // at least one epoch of history)
        let mut es = EarlyStop::new(0, 0.01, 0.05);
        assert_eq!(es.patience, 1);
        assert!(!es.observe_epoch(5.0), "stopped with no history");
        // flat second epoch: plateau over patience=1 window, weights
        // trivially stable
        assert!(es.observe_epoch(5.0));
    }

    #[test]
    fn weight_stability_window_requires_patience_consecutive_epochs() {
        // losses plateau immediately, but weights only settle later: the
        // stop must wait for `patience` *consecutive* stable epochs
        let mut es = EarlyStop::new(2, 0.01, 0.1);
        let mut stop_epoch = None;
        for epoch in 0..8 {
            // epochs 0-2 oscillate beyond w_tol, 3+ are frozen
            let w = if epoch < 3 && epoch % 2 == 0 { 2.0 } else { 1.0 };
            es.observe_weights(&[w, 1.0]);
            es.observe_weights(&[1.0, 1.0]);
            if es.observe_epoch(3.0) {
                stop_epoch = Some(epoch);
                break;
            }
        }
        // stable from epoch 3 on; two consecutive stable epochs = 3, 4
        assert_eq!(stop_epoch, Some(4));
    }

    #[test]
    fn weight_stability_counter_resets_on_movement() {
        let mut es = EarlyStop::new(2, 0.01, 0.1);
        // one stable epoch...
        es.observe_weights(&[1.0, 1.0]);
        es.observe_weights(&[1.0, 1.0]);
        assert!(!es.observe_epoch(3.0));
        // ...then a jump: the stable-epoch streak must restart
        es.observe_weights(&[1.0, 1.0]);
        es.observe_weights(&[1.5, 0.5]);
        assert!(!es.observe_epoch(3.0));
        assert_eq!(es.w_stable_epochs, 0);
        // two fresh stable epochs rebuild the streak and trigger the stop
        es.observe_weights(&[1.5, 0.5]);
        assert!(!es.observe_epoch(3.0));
        es.observe_weights(&[1.5, 0.5]);
        assert!(es.observe_epoch(3.0));
    }

    #[test]
    fn relative_tolerance_scales_with_loss_magnitude() {
        // a 0.5-absolute improvement is large at loss 1.0 but negligible at
        // loss 1000: rel_tol must treat them differently
        let mut small = EarlyStop::new(1, 0.01, 0.05);
        assert!(!small.observe_epoch(1.0));
        // 0.5/1.0 = 50% improvement >> 1% tolerance: keep training
        assert!(!small.observe_epoch(0.5));

        let mut big = EarlyStop::new(1, 0.01, 0.05);
        assert!(!big.observe_epoch(1000.0));
        // 0.5/1000 = 0.05% improvement < 1% tolerance: plateau, stop
        assert!(big.observe_epoch(999.5));
    }

    #[test]
    fn relative_tolerance_handles_worsening_loss() {
        // loss going *up* is improvement < 0 < rel_tol: must also stop
        // (with stable weights) rather than wait forever
        let mut es = EarlyStop::new(1, 0.01, 0.05);
        assert!(!es.observe_epoch(2.0));
        assert!(es.observe_epoch(2.5));
    }
}

//! Seeded property runner + common generators.

use crate::util::rng::Pcg64;

/// A generator: draws a case from the RNG.
pub type Gen<T> = fn(&mut Pcg64) -> T;

/// Run `prop` over `cases` seeded inputs; panic with a replayable report on
/// the first failure. `base_seed` pins the whole suite.
pub fn prop_check<T: std::fmt::Debug>(
    name: &str,
    base_seed: u64,
    cases: usize,
    gen: impl Fn(&mut Pcg64) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
        let mut rng = Pcg64::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}):\n  \
                 {msg}\n  input: {input:?}"
            );
        }
    }
}

/// Common generators --------------------------------------------------------

/// Vec<f32> of length in [1, max_len], values in [lo, hi).
pub fn vec_f32(rng: &mut Pcg64, max_len: usize, lo: f32, hi: f32) -> Vec<f32> {
    let n = 1 + rng.next_below(max_len as u64) as usize;
    (0..n)
        .map(|_| lo + (hi - lo) * rng.next_f32())
        .collect()
}

/// A batch-shaped pair (loss, gnorm) with positive entries.
pub fn loss_gnorm(rng: &mut Pcg64, max_len: usize) -> (Vec<f32>, Vec<f32>) {
    let n = 2 + rng.next_below(max_len as u64 - 1) as usize;
    let loss = (0..n).map(|_| 1e-3 + 4.0 * rng.next_f32()).collect();
    let gnorm = (0..n).map(|_| 1e-3 + 2.0 * rng.next_f32()).collect();
    (loss, gnorm)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        prop_check(
            "trivial",
            1,
            50,
            |rng| rng.next_below(100),
            |_| {
                // count via a thread-local-free trick: the closure can't
                // capture &mut here, so just verify it doesn't panic
                Ok(())
            },
        );
        count += 1;
        assert_eq!(count, 1);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn failing_property_reports() {
        prop_check(
            "always-fails",
            2,
            10,
            |rng| rng.next_below(10),
            |v| Err(format!("saw {v}")),
        );
    }

    #[test]
    fn generators_respect_bounds() {
        let mut rng = Pcg64::new(3);
        for _ in 0..100 {
            let v = vec_f32(&mut rng, 20, -1.0, 1.0);
            assert!(!v.is_empty() && v.len() <= 20);
            assert!(v.iter().all(|&x| (-1.0..1.0).contains(&x)));
            let (l, g) = loss_gnorm(&mut rng, 50);
            assert_eq!(l.len(), g.len());
            assert!(l.iter().all(|&x| x > 0.0));
        }
    }

    #[test]
    fn same_seed_same_cases() {
        let mut a = Pcg64::new(7);
        let mut b = Pcg64::new(7);
        assert_eq!(vec_f32(&mut a, 10, 0.0, 1.0), vec_f32(&mut b, 10, 0.0, 1.0));
    }
}

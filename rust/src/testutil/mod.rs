//! Mini property-testing framework (no `proptest` offline).
//!
//! [`prop_check`] runs a property over N seeded random cases; on failure it
//! reports the seed and case index so the exact case replays. Generators
//! are just closures over [`Pcg64`], composed with plain functions.

pub mod prop;

pub use prop::{prop_check, Gen};

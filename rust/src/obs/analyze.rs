//! Offline trace profiler: `adaselection trace-analyze J [J...]`.
//!
//! Merges one run's journals — the coordinator journal plus each
//! worker's `PATH.node<i>` file — by `(round, node)` and computes the
//! four attribution views the paper's efficiency story needs:
//!
//! * **per-arm selection efficiency** — forward rows, backward rows
//!   (trained + replayed) and prequential-loss delta attributed to each
//!   bandit arm per round window, weighted by the arm's posted weight on
//!   every tick (ticks without weights fall back to one implicit arm);
//! * **barrier critical path** — per-round barrier open→all-ready
//!   duration from `span` events, the per-node ready lags behind it, a
//!   straggler table (who was slowest, how often) and a lag histogram;
//! * **wire bandwidth** — gossip vs merge bytes per round and in total;
//! * **drift timeline** — every detector fire (cumulative `drift`
//!   increments per node) with the effective γ around it, so boosts are
//!   visible next to the event that caused them;
//! * **alert timeline** — every health-rule firing/resolved transition
//!   (schema-v3 `alert` events) ordered by round, with per-rule firing
//!   totals and the set still unresolved at end of journal;
//! * **per-kernel quantiles** — p50/p95/p99 per-tick seconds for every
//!   backend kernel, rebuilt offline from the `kernel:<name>` entries
//!   the continuous profiler writes into each tick's `phases` object.
//!
//! The report is canonical: sorted-key JSON (the [`Json`] writer emits
//! `BTreeMap` order), derived purely from the input bytes — identical
//! journals produce byte-identical reports, pinned by `input_hash` /
//! `report_hash` (FNV-1a/64). Every line must validate against schema
//! v1–v3 ([`trace::validate_line`]); any invalid line aborts the
//! analysis with its `file:line` location.

use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

use crate::obs::trace;
use crate::util::json::Json;

/// FNV-1a/64 offset basis (the 32-bit sibling lives in `stream::tick`).
const FNV64_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV64_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Lag-histogram upper bounds, seconds (`None` = +Inf overflow bucket).
const LAG_BOUNDS: [f64; 5] = [0.0001, 0.001, 0.01, 0.1, 1.0];

/// Arm id used when a tick posts no bandit weights (single-method runs).
const IMPLICIT_ARM: &str = "(single)";

fn fnv1a64(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV64_PRIME);
    }
    h
}

/// One `kind:"tick"` line, decoded past validation.
struct TickRow {
    node: usize,
    tick: u64,
    round: u64,
    gamma: f64,
    arrivals: u64,
    trained: u64,
    replayed: u64,
    forward: u64,
    /// cumulative detector fires as of this tick
    drift: u64,
    weights: Vec<(String, f64)>,
    rolling_loss: Option<f64>,
    /// `kernel:<name>` sub-phase seconds this tick, prefix stripped.
    kernels: Vec<(String, f64)>,
}

struct WireRow {
    kind: String,
    round: u64,
    bytes: u64,
}

struct SpanRow {
    name: String,
    round: u64,
    tick: u64,
    node: Option<usize>,
    duration: f64,
}

struct AlertRow {
    rule: String,
    state: String,
    round: u64,
    tick: u64,
    node: Option<usize>,
    value: Option<f64>,
    threshold: Option<f64>,
}

#[derive(Default)]
struct Journals {
    ticks: Vec<TickRow>,
    wire: Vec<WireRow>,
    spans: Vec<SpanRow>,
    alerts: Vec<AlertRow>,
    lines: u64,
    versions: BTreeSet<u64>,
}

fn parse_line(name: &str, lineno: usize, line: &str, out: &mut Journals) -> anyhow::Result<()> {
    let ev = trace::validate_line(line)
        .map_err(|e| anyhow::anyhow!("{name}:{}: {e}", lineno + 1))?;
    let j = Json::parse(line).expect("validated line re-parses");
    out.lines += 1;
    out.versions.insert(j.at(&["v"])?.as_usize()? as u64);
    match ev.kind.as_str() {
        "tick" => {
            let weights = j
                .at(&["weights"])?
                .as_obj()?
                .iter()
                .filter_map(|(arm, w)| w.as_f64().ok().map(|w| (arm.clone(), w)))
                .collect();
            let rolling_loss = j
                .get("rolling")
                .and_then(|r| r.get("loss"))
                .and_then(|l| l.as_f64().ok());
            let kernels = j
                .at(&["phases"])?
                .as_obj()?
                .iter()
                .filter_map(|(name, secs)| {
                    let k = name.strip_prefix("kernel:")?;
                    secs.as_f64().ok().map(|s| (k.to_string(), s))
                })
                .collect();
            out.ticks.push(TickRow {
                node: ev.node.unwrap_or(0),
                tick: ev.tick,
                round: ev.round,
                gamma: j.at(&["gamma"])?.as_f64().unwrap_or(0.0),
                arrivals: j.at(&["arrivals"])?.as_usize()? as u64,
                trained: j.at(&["trained"])?.as_usize()? as u64,
                replayed: j.at(&["replayed"])?.as_usize()? as u64,
                forward: j.at(&["forward"])?.as_usize()? as u64,
                drift: j.at(&["drift"])?.as_usize()? as u64,
                weights,
                rolling_loss,
                kernels,
            });
        }
        "gossip" | "merge" => out.wire.push(WireRow {
            kind: ev.kind,
            round: ev.round,
            bytes: j.at(&["bytes"])?.as_usize()? as u64,
        }),
        "span" => out.spans.push(SpanRow {
            name: ev.name.clone().unwrap_or_default(),
            round: ev.round,
            tick: ev.tick,
            node: ev.node,
            duration: j.at(&["duration"])?.as_f64()?,
        }),
        "alert" => {
            let (rule, state) = ev.alert.clone().expect("validated alert carries rule/state");
            out.alerts.push(AlertRow {
                rule,
                state,
                round: ev.round,
                tick: ev.tick,
                node: ev.node,
                value: j.get("value").and_then(|v| v.as_f64().ok()),
                threshold: j.get("threshold").and_then(|v| v.as_f64().ok()),
            });
        }
        _ => unreachable!("validate_line admits only known kinds"),
    }
    Ok(())
}

/// Per-arm accumulator for one window (= one barrier round).
#[derive(Default, Clone)]
struct ArmShare {
    forward: f64,
    backward: f64,
    loss_delta: f64,
    weight_sum: f64,
    weighted_ticks: u64,
}

fn attribution(ticks: &[TickRow]) -> (Json, Json) {
    // window = barrier round (stream journals collapse to round 0)
    let mut windows: BTreeMap<u64, BTreeMap<String, ArmShare>> = BTreeMap::new();
    let mut window_loss: BTreeMap<u64, f64> = BTreeMap::new();
    let mut ordered: Vec<&TickRow> = ticks.iter().collect();
    ordered.sort_by_key(|t| (t.round, t.tick, t.node));
    for t in &ordered {
        let arms = windows.entry(t.round).or_default();
        let fwd = t.forward as f64;
        let bwd = (t.trained + t.replayed) as f64;
        let wsum: f64 = t.weights.iter().map(|(_, w)| w.max(0.0)).sum();
        if t.weights.is_empty() || wsum <= 0.0 {
            let a = arms.entry(IMPLICIT_ARM.to_string()).or_default();
            a.forward += fwd;
            a.backward += bwd;
        } else {
            for (arm, w) in &t.weights {
                let share = w.max(0.0) / wsum;
                let a = arms.entry(arm.clone()).or_default();
                a.forward += fwd * share;
                a.backward += bwd * share;
                a.weight_sum += w.max(0.0);
                a.weighted_ticks += 1;
            }
        }
        if let Some(loss) = t.rolling_loss {
            window_loss.insert(t.round, loss); // ordered scan → last wins
        }
    }
    // prequential-loss delta per window, split across arms by backward share
    let mut prev_loss: Option<f64> = None;
    for (round, arms) in windows.iter_mut() {
        let Some(&loss) = window_loss.get(round) else { continue };
        let delta = loss - prev_loss.unwrap_or(loss);
        prev_loss = Some(loss);
        let total_bwd: f64 = arms.values().map(|a| a.backward).sum();
        if total_bwd > 0.0 {
            for a in arms.values_mut() {
                a.loss_delta = delta * a.backward / total_bwd;
            }
        }
    }
    // totals across windows
    let mut totals: BTreeMap<String, ArmShare> = BTreeMap::new();
    let mut arm_windows: BTreeMap<String, u64> = BTreeMap::new();
    for arms in windows.values() {
        for (arm, a) in arms {
            let t = totals.entry(arm.clone()).or_default();
            t.forward += a.forward;
            t.backward += a.backward;
            t.loss_delta += a.loss_delta;
            t.weight_sum += a.weight_sum;
            t.weighted_ticks += a.weighted_ticks;
            *arm_windows.entry(arm.clone()).or_default() += 1;
        }
    }
    let arm_json = |a: &ArmShare, windows: u64| {
        let mut m = vec![
            ("backward_rows", Json::from(round3(a.backward))),
            ("forward_rows", Json::from(round3(a.forward))),
            ("loss_delta", Json::from(round6(a.loss_delta))),
            ("windows", Json::from(windows as usize)),
        ];
        if a.weighted_ticks > 0 {
            m.push((
                "mean_weight",
                Json::from(round6(a.weight_sum / a.weighted_ticks as f64)),
            ));
        }
        Json::obj(m)
    };
    let totals_json = Json::Obj(
        totals
            .iter()
            .map(|(arm, a)| (arm.clone(), arm_json(a, arm_windows[arm])))
            .collect(),
    );
    let per_window = Json::Arr(
        windows
            .iter()
            .map(|(round, arms)| {
                Json::obj(vec![
                    (
                        "arms",
                        Json::Obj(
                            arms.iter().map(|(arm, a)| (arm.clone(), arm_json(a, 1))).collect(),
                        ),
                    ),
                    ("round", Json::from(*round as usize)),
                ])
            })
            .collect(),
    );
    (totals_json, per_window)
}

fn barriers(spans: &[SpanRow]) -> Json {
    let mut rounds: BTreeMap<u64, (Option<(u64, f64)>, Vec<(usize, f64)>)> = BTreeMap::new();
    for s in spans {
        let entry = rounds.entry(s.round).or_default();
        match s.name.as_str() {
            "barrier" => entry.0 = Some((s.tick, s.duration)),
            "ready_lag" => {
                if let Some(n) = s.node {
                    entry.1.push((n, s.duration));
                }
            }
            _ => {}
        }
    }
    let mut per_round = Vec::new();
    let mut hist = vec![0u64; LAG_BOUNDS.len() + 1];
    let mut by_node: BTreeMap<usize, (u64, f64, f64, u64)> = BTreeMap::new(); // slowest, max, sum, count
    for (round, (barrier, mut lags)) in rounds {
        lags.sort_by(|a, b| a.0.cmp(&b.0));
        let mut straggler: Option<(usize, f64)> = None;
        for &(node, lag) in &lags {
            let bucket = LAG_BOUNDS.iter().position(|&b| lag <= b).unwrap_or(LAG_BOUNDS.len());
            hist[bucket] += 1;
            let e = by_node.entry(node).or_insert((0, 0.0, 0.0, 0));
            e.1 = e.1.max(lag);
            e.2 += lag;
            e.3 += 1;
            if straggler.map(|(_, worst)| lag > worst).unwrap_or(true) {
                straggler = Some((node, lag));
            }
        }
        if let Some((node, _)) = straggler {
            by_node.get_mut(&node).unwrap().0 += 1;
        }
        let mut row = vec![("round", Json::from(round as usize))];
        if let Some((tick, duration)) = barrier {
            row.push(("duration", Json::from(round6(duration))));
            row.push(("tick", Json::from(tick as usize)));
        }
        row.push((
            "ready",
            Json::Arr(
                lags.iter()
                    .map(|&(node, lag)| {
                        Json::obj(vec![
                            ("lag", Json::from(round6(lag))),
                            ("node", Json::from(node)),
                        ])
                    })
                    .collect(),
            ),
        ));
        if let Some((node, lag)) = straggler {
            row.push((
                "straggler",
                Json::obj(vec![("lag", Json::from(round6(lag))), ("node", Json::from(node))]),
            ));
        }
        per_round.push(Json::obj(row));
    }
    let histogram = Json::Arr(
        hist.iter()
            .enumerate()
            .map(|(i, &count)| {
                Json::obj(vec![
                    ("count", Json::from(count as usize)),
                    (
                        "le",
                        LAG_BOUNDS.get(i).map(|&b| Json::from(b)).unwrap_or(Json::Null),
                    ),
                ])
            })
            .collect(),
    );
    let stragglers = Json::Arr(
        by_node
            .iter()
            .map(|(&node, &(slowest, max, sum, count))| {
                Json::obj(vec![
                    ("max_lag", Json::from(round6(max))),
                    (
                        "mean_lag",
                        Json::from(round6(if count > 0 { sum / count as f64 } else { 0.0 })),
                    ),
                    ("node", Json::from(node)),
                    ("rounds_slowest", Json::from(slowest as usize)),
                ])
            })
            .collect(),
    );
    let n_rounds = per_round.len();
    Json::obj(vec![
        ("lag_histogram", histogram),
        ("per_round", Json::Arr(per_round)),
        ("rounds", Json::from(n_rounds)),
        ("stragglers", stragglers),
    ])
}

fn bandwidth(wire: &[WireRow]) -> Json {
    let mut per_round: BTreeMap<u64, (u64, u64)> = BTreeMap::new();
    let (mut gossip_total, mut merge_total) = (0u64, 0u64);
    for w in wire {
        let e = per_round.entry(w.round).or_default();
        if w.kind == "gossip" {
            e.0 += w.bytes;
            gossip_total += w.bytes;
        } else {
            e.1 += w.bytes;
            merge_total += w.bytes;
        }
    }
    Json::obj(vec![
        ("gossip_bytes_total", Json::from(gossip_total as usize)),
        ("merge_bytes_total", Json::from(merge_total as usize)),
        (
            "per_round",
            Json::Arr(
                per_round
                    .iter()
                    .map(|(&round, &(g, m))| {
                        Json::obj(vec![
                            ("gossip_bytes", Json::from(g as usize)),
                            ("merge_bytes", Json::from(m as usize)),
                            ("round", Json::from(round as usize)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

fn drift_timeline(ticks: &[TickRow]) -> Json {
    // γ base = the smallest effective γ seen; boosts only push γ up
    let gamma_base = ticks
        .iter()
        .map(|t| t.gamma)
        .filter(|g| g.is_finite() && *g > 0.0)
        .fold(f64::INFINITY, f64::min);
    let gamma_base = if gamma_base.is_finite() { gamma_base } else { 0.0 };
    let mut per_node: BTreeMap<usize, Vec<&TickRow>> = BTreeMap::new();
    for t in ticks {
        per_node.entry(t.node).or_default().push(t);
    }
    let mut events = Vec::new();
    for rows in per_node.values_mut() {
        rows.sort_by_key(|t| t.tick);
        let mut prev = 0u64;
        for (i, t) in rows.iter().enumerate() {
            if t.drift > prev {
                let gamma_next = rows.get(i + 1).map(|n| n.gamma).unwrap_or(t.gamma);
                let boosted = gamma_next > gamma_base * 1.000001 || t.gamma > gamma_base * 1.000001;
                events.push((
                    t.round,
                    t.tick,
                    t.node,
                    Json::obj(vec![
                        ("boosted", Json::from(boosted)),
                        ("fires", Json::from((t.drift - prev) as usize)),
                        ("gamma", Json::from(round6(t.gamma))),
                        ("gamma_next", Json::from(round6(gamma_next))),
                        ("node", Json::from(t.node)),
                        ("round", Json::from(t.round as usize)),
                        ("tick", Json::from(t.tick as usize)),
                    ]),
                ));
            }
            prev = t.drift;
        }
    }
    events.sort_by_key(|(round, tick, node, _)| (*round, *tick, *node));
    let total: usize = ticks
        .iter()
        .map(|t| t.node)
        .collect::<BTreeSet<_>>()
        .iter()
        .map(|n| {
            per_node[n]
                .last()
                .map(|t| t.drift as usize)
                .unwrap_or(0)
        })
        .sum();
    Json::obj(vec![
        ("events", Json::Arr(events.into_iter().map(|(_, _, _, j)| j).collect())),
        ("gamma_base", Json::from(round6(gamma_base))),
        ("total", Json::from(total)),
    ])
}

fn alert_timeline(alerts: &[AlertRow]) -> Json {
    let mut ordered: Vec<&AlertRow> = alerts.iter().collect();
    ordered.sort_by(|a, b| {
        (a.round, a.tick, a.rule.as_str(), a.node).cmp(&(b.round, b.tick, b.rule.as_str(), b.node))
    });
    let mut firing_total: BTreeMap<String, u64> = BTreeMap::new();
    let mut last_state: BTreeMap<(String, Option<usize>), String> = BTreeMap::new();
    let mut events = Vec::new();
    for a in &ordered {
        if a.state == "firing" {
            *firing_total.entry(a.rule.clone()).or_default() += 1;
        }
        last_state.insert((a.rule.clone(), a.node), a.state.clone());
        let mut row = vec![
            ("round", Json::from(a.round as usize)),
            ("rule", Json::from(a.rule.as_str())),
            ("state", Json::from(a.state.as_str())),
            (
                "threshold",
                a.threshold.map(|v| Json::from(round6(v))).unwrap_or(Json::Null),
            ),
            ("tick", Json::from(a.tick as usize)),
            ("value", a.value.map(|v| Json::from(round6(v))).unwrap_or(Json::Null)),
        ];
        if let Some(n) = a.node {
            row.push(("node", Json::from(n)));
        }
        events.push(Json::obj(row));
    }
    let unresolved = Json::Arr(
        last_state
            .iter()
            .filter(|(_, state)| state.as_str() == "firing")
            .map(|((rule, node), _)| {
                let mut row = vec![("rule", Json::from(rule.as_str()))];
                if let Some(n) = node {
                    row.push(("node", Json::from(*n)));
                }
                Json::obj(row)
            })
            .collect(),
    );
    Json::obj(vec![
        ("events", Json::Arr(events)),
        (
            "firing_total",
            Json::Obj(
                firing_total
                    .iter()
                    .map(|(rule, n)| (rule.clone(), Json::from(*n as usize)))
                    .collect(),
            ),
        ),
        ("unresolved", unresolved),
    ])
}

/// Per-kernel per-tick-seconds quantiles, rebuilt from the
/// `kernel:<name>` phase entries the continuous profiler journals.
fn kernel_quantiles(ticks: &[TickRow]) -> Json {
    let mut per: BTreeMap<String, Vec<f64>> = BTreeMap::new();
    for t in ticks {
        for (kernel, secs) in &t.kernels {
            per.entry(kernel.clone()).or_default().push(*secs);
        }
    }
    // nearest-rank quantile over the sorted per-tick samples
    fn rank(vals: &[f64], q: f64) -> f64 {
        let idx = ((vals.len() as f64 * q).ceil() as usize).max(1) - 1;
        vals[idx.min(vals.len() - 1)]
    }
    Json::Obj(
        per.into_iter()
            .map(|(kernel, mut vals)| {
                vals.sort_by(|a, b| a.total_cmp(b));
                let total: f64 = vals.iter().sum();
                (
                    kernel,
                    Json::obj(vec![
                        ("p50_seconds", Json::from(round9(rank(&vals, 0.50)))),
                        ("p95_seconds", Json::from(round9(rank(&vals, 0.95)))),
                        ("p99_seconds", Json::from(round9(rank(&vals, 0.99)))),
                        ("ticks", Json::from(vals.len())),
                        ("total_seconds", Json::from(round9(total))),
                    ]),
                )
            })
            .collect(),
    )
}

fn round3(v: f64) -> f64 {
    (v * 1e3).round() / 1e3
}

fn round6(v: f64) -> f64 {
    (v * 1e6).round() / 1e6
}

/// Nanosecond precision — kernel timings are often sub-microsecond.
fn round9(v: f64) -> f64 {
    (v * 1e9).round() / 1e9
}

/// Analyze in-memory journals: `(name, contents)` pairs. The unit of the
/// CLI path and the test seam — deterministic in its inputs alone.
pub fn analyze_inputs(inputs: &[(String, String)]) -> anyhow::Result<Json> {
    anyhow::ensure!(!inputs.is_empty(), "trace-analyze: no journals given");
    let mut sorted: Vec<&(String, String)> = inputs.iter().collect();
    sorted.sort_by(|a, b| a.0.cmp(&b.0));
    let mut input_hash = FNV64_OFFSET;
    let mut data = Journals::default();
    for (name, text) in &sorted {
        input_hash = fnv1a64(input_hash, name.as_bytes());
        input_hash = fnv1a64(input_hash, text.as_bytes());
        for (lineno, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            parse_line(name, lineno, line, &mut data)?;
        }
    }
    anyhow::ensure!(data.lines > 0, "trace-analyze: journals contain no events");

    let nodes: BTreeSet<usize> = data.ticks.iter().map(|t| t.node).collect();
    let max_round = data
        .ticks
        .iter()
        .map(|t| t.round)
        .chain(data.spans.iter().map(|s| s.round))
        .chain(data.wire.iter().map(|w| w.round))
        .max()
        .unwrap_or(0);
    let (arm_totals, per_window) = attribution(&data.ticks);
    let totals = Json::obj(vec![
        ("arrivals", Json::from(data.ticks.iter().map(|t| t.arrivals).sum::<u64>() as usize)),
        ("forward", Json::from(data.ticks.iter().map(|t| t.forward).sum::<u64>() as usize)),
        ("nodes", Json::from(nodes.len())),
        ("replayed", Json::from(data.ticks.iter().map(|t| t.replayed).sum::<u64>() as usize)),
        ("ticks", Json::from(data.ticks.len())),
        ("trained", Json::from(data.ticks.iter().map(|t| t.trained).sum::<u64>() as usize)),
    ]);
    let mut report = Json::obj(vec![
        ("alerts", alert_timeline(&data.alerts)),
        (
            "arms",
            Json::obj(vec![("per_window", per_window), ("totals", arm_totals)]),
        ),
        ("bandwidth", bandwidth(&data.wire)),
        ("barriers", barriers(&data.spans)),
        ("drift", drift_timeline(&data.ticks)),
        ("kernels", kernel_quantiles(&data.ticks)),
        (
            "inputs",
            Json::obj(vec![
                (
                    "files",
                    Json::arr_str(&sorted.iter().map(|(n, _)| n.clone()).collect::<Vec<_>>()),
                ),
                ("input_hash", Json::from(format!("{input_hash:016x}").as_str())),
                ("lines", Json::from(data.lines as usize)),
                (
                    "schema_versions",
                    Json::Arr(data.versions.iter().map(|&v| Json::from(v as usize)).collect()),
                ),
            ]),
        ),
        ("rounds", Json::from(max_round as usize)),
        ("ticks", totals),
    ]);
    let report_hash = format!("{:016x}", fnv1a64(FNV64_OFFSET, report.to_string().as_bytes()));
    if let Json::Obj(m) = &mut report {
        m.insert("report_hash".to_string(), Json::from(report_hash.as_str()));
    }
    Ok(report)
}

/// Read and analyze journal files from disk (the CLI entry point).
pub fn analyze_files<P: AsRef<Path>>(paths: &[P]) -> anyhow::Result<Json> {
    let mut inputs = Vec::new();
    for p in paths {
        let p = p.as_ref();
        let name = p
            .file_name()
            .map(|n| n.to_string_lossy().into_owned())
            .unwrap_or_else(|| p.display().to_string());
        let text = std::fs::read_to_string(p)
            .map_err(|e| anyhow::anyhow!("trace-analyze: read {}: {e}", p.display()))?;
        inputs.push((name, text));
    }
    analyze_inputs(&inputs)
}

/// Human-readable summary table for a report from [`analyze_inputs`].
pub fn render_summary(report: &Json) -> String {
    let mut out = String::new();
    let usize_at = |path: &[&str]| report.at(path).and_then(|j| j.as_usize()).unwrap_or(0);
    out.push_str(&format!(
        "trace-analyze: {} lines across {} file(s), {} round(s), {} tick event(s)\n",
        usize_at(&["inputs", "lines"]),
        report
            .at(&["inputs", "files"])
            .and_then(|f| f.as_arr().map(|a| a.len()))
            .unwrap_or(0),
        usize_at(&["rounds"]),
        usize_at(&["ticks", "ticks"]),
    ));
    out.push_str(&format!(
        "bandwidth: gossip {} B, merge {} B\n",
        usize_at(&["bandwidth", "gossip_bytes_total"]),
        usize_at(&["bandwidth", "merge_bytes_total"]),
    ));
    if let Ok(arms) = report.at(&["arms", "totals"]).and_then(|a| a.as_obj()) {
        out.push_str("arm                forward     backward   loss-delta\n");
        for (arm, a) in arms {
            out.push_str(&format!(
                "{arm:<16} {:>10.1} {:>12.1} {:>12.4}\n",
                a.get("forward_rows").and_then(|v| v.as_f64().ok()).unwrap_or(0.0),
                a.get("backward_rows").and_then(|v| v.as_f64().ok()).unwrap_or(0.0),
                a.get("loss_delta").and_then(|v| v.as_f64().ok()).unwrap_or(0.0),
            ));
        }
    }
    if let Ok(rows) = report.at(&["barriers", "stragglers"]).and_then(|s| s.as_arr()) {
        if !rows.is_empty() {
            out.push_str("node   slowest-in   max-lag(s)   mean-lag(s)\n");
            for r in rows {
                out.push_str(&format!(
                    "{:<6} {:>10} {:>12.6} {:>13.6}\n",
                    r.get("node").and_then(|v| v.as_usize().ok()).unwrap_or(0),
                    r.get("rounds_slowest").and_then(|v| v.as_usize().ok()).unwrap_or(0),
                    r.get("max_lag").and_then(|v| v.as_f64().ok()).unwrap_or(0.0),
                    r.get("mean_lag").and_then(|v| v.as_f64().ok()).unwrap_or(0.0),
                ));
            }
        }
    }
    let drift_events = report
        .at(&["drift", "events"])
        .and_then(|e| e.as_arr().map(|a| a.len()))
        .unwrap_or(0);
    let boosted = report
        .at(&["drift", "events"])
        .ok()
        .and_then(|e| e.as_arr().ok())
        .map(|a| {
            a.iter()
                .filter(|e| e.get("boosted").and_then(|b| b.as_bool().ok()).unwrap_or(false))
                .count()
        })
        .unwrap_or(0);
    out.push_str(&format!(
        "drift: {} event(s), {} with a γ boost visible\n",
        drift_events, boosted
    ));
    let alert_events = report
        .at(&["alerts", "events"])
        .and_then(|e| e.as_arr().map(|a| a.len()))
        .unwrap_or(0);
    let unresolved = report
        .at(&["alerts", "unresolved"])
        .and_then(|e| e.as_arr().map(|a| a.len()))
        .unwrap_or(0);
    out.push_str(&format!(
        "alerts: {} transition(s), {} unresolved at end of journal\n",
        alert_events, unresolved
    ));
    if let Ok(kernels) = report.at(&["kernels"]).and_then(|k| k.as_obj()) {
        if !kernels.is_empty() {
            out.push_str("kernel                  ticks      p50(s)      p95(s)      p99(s)\n");
            for (kernel, k) in kernels {
                out.push_str(&format!(
                    "{kernel:<20} {:>8} {:>11.6} {:>11.6} {:>11.6}\n",
                    k.get("ticks").and_then(|v| v.as_usize().ok()).unwrap_or(0),
                    k.get("p50_seconds").and_then(|v| v.as_f64().ok()).unwrap_or(0.0),
                    k.get("p95_seconds").and_then(|v| v.as_f64().ok()).unwrap_or(0.0),
                    k.get("p99_seconds").and_then(|v| v.as_f64().ok()).unwrap_or(0.0),
                ));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tick_line(
        v: u64,
        node: usize,
        tick: u64,
        round: u64,
        forward: u64,
        trained: u64,
        weights: &[(&str, f64)],
        drift: u64,
        gamma: f64,
        loss: Option<f64>,
    ) -> String {
        let mut pairs = vec![
            ("v", Json::from(v as usize)),
            ("kind", Json::from("tick")),
            ("tick", Json::from(tick as usize)),
            ("node", Json::from(node)),
            ("gamma", Json::from(gamma)),
            ("arrivals", Json::from(forward as usize)),
            ("trained", Json::from(trained as usize)),
            ("replayed", Json::from(0usize)),
            ("forward", Json::from(forward as usize)),
            ("drift", Json::from(drift as usize)),
            (
                "weights",
                Json::Obj(weights.iter().map(|(a, w)| (a.to_string(), Json::from(*w))).collect()),
            ),
            (
                "store",
                Json::obj(vec![
                    ("live", Json::from(1usize)),
                    ("capacity", Json::from(64usize)),
                    ("hits", Json::from(0usize)),
                    ("misses", Json::from(0usize)),
                    ("evictions", Json::from(0usize)),
                ]),
            ),
            ("phases", Json::obj(vec![])),
        ];
        if v >= 2 {
            pairs.push(("round", Json::from(round as usize)));
        }
        if let Some(l) = loss {
            pairs.push(("rolling", Json::obj(vec![("loss", Json::from(l)), ("acc", Json::Null)])));
        }
        Json::obj(pairs).to_string()
    }

    fn span_line(name: &str, round: u64, tick: u64, node: Option<usize>, dur: f64) -> String {
        let mut pairs = vec![
            ("v", Json::from(2usize)),
            ("kind", Json::from("span")),
            ("name", Json::from(name)),
            ("round", Json::from(round as usize)),
            ("tick", Json::from(tick as usize)),
            ("start", Json::from(0.5)),
            ("duration", Json::from(dur)),
        ];
        if let Some(n) = node {
            pairs.push(("node", Json::from(n)));
        }
        Json::obj(pairs).to_string()
    }

    fn wire_line(kind: &str, round: u64, tick: u64, bytes: u64) -> String {
        Json::obj(vec![
            ("v", Json::from(2usize)),
            ("kind", Json::from(kind)),
            ("round", Json::from(round as usize)),
            ("tick", Json::from(tick as usize)),
            ("bytes", Json::from(bytes as usize)),
        ])
        .to_string()
    }

    fn sample_inputs() -> Vec<(String, String)> {
        let coord = [
            span_line("barrier", 1, 16, None, 0.02),
            span_line("ready_lag", 1, 16, Some(0), 0.005),
            span_line("ready_lag", 1, 16, Some(1), 0.02),
            span_line("barrier", 2, 32, None, 0.01),
            span_line("ready_lag", 2, 32, Some(0), 0.01),
            span_line("ready_lag", 2, 32, Some(1), 0.002),
            wire_line("gossip", 1, 16, 2048),
            wire_line("merge", 2, 32, 8192),
        ]
        .join("\n");
        let n0 = [
            tick_line(2, 0, 0, 1, 100, 50, &[("a", 0.75), ("b", 0.25)], 0, 0.5, Some(2.0)),
            tick_line(2, 0, 1, 2, 100, 50, &[("a", 0.5), ("b", 0.5)], 1, 0.8, Some(1.0)),
        ]
        .join("\n");
        let n1 = [
            tick_line(2, 1, 0, 1, 60, 30, &[("a", 0.75), ("b", 0.25)], 0, 0.5, None),
            tick_line(2, 1, 1, 2, 60, 30, &[("a", 0.5), ("b", 0.5)], 0, 0.5, None),
        ]
        .join("\n");
        vec![
            ("trace.jsonl".to_string(), coord),
            ("trace.jsonl.node0".to_string(), n0),
            ("trace.jsonl.node1".to_string(), n1),
        ]
    }

    #[test]
    fn report_is_deterministic_and_hashed() {
        let inputs = sample_inputs();
        let a = analyze_inputs(&inputs).unwrap().to_string();
        let b = analyze_inputs(&inputs).unwrap().to_string();
        assert_eq!(a, b, "identical inputs must produce byte-identical reports");
        let j = Json::parse(&a).unwrap();
        assert_eq!(j.at(&["inputs", "lines"]).unwrap().as_usize().unwrap(), 12);
        assert!(j.at(&["report_hash"]).unwrap().as_str().unwrap().len() == 16);
        // input order must not matter: the analyzer sorts by file name
        let mut rev = inputs.clone();
        rev.reverse();
        assert_eq!(a, analyze_inputs(&rev).unwrap().to_string());
    }

    #[test]
    fn per_arm_attribution_follows_weights() {
        let j = analyze_inputs(&sample_inputs()).unwrap();
        // round 1: both nodes posted {a: .75, b: .25} over 160 forward rows
        let arms = j.at(&["arms", "totals"]).unwrap().as_obj().unwrap();
        assert!(arms.contains_key("a") && arms.contains_key("b"));
        let fwd_a = arms["a"].at(&["forward_rows"]).unwrap().as_f64().unwrap();
        let fwd_b = arms["b"].at(&["forward_rows"]).unwrap().as_f64().unwrap();
        // a: 160*.75 + 160*.5 = 200; b: 160*.25 + 160*.5 = 120
        assert!((fwd_a - 200.0).abs() < 1e-6, "fwd_a = {fwd_a}");
        assert!((fwd_b - 120.0).abs() < 1e-6, "fwd_b = {fwd_b}");
        // loss fell 2.0 → 1.0 across windows; round-2 delta −1 split 50/50
        let dl_a = arms["a"].at(&["loss_delta"]).unwrap().as_f64().unwrap();
        assert!((dl_a - (-0.5)).abs() < 1e-6, "dl_a = {dl_a}");
        let windows = j.at(&["arms", "per_window"]).unwrap().as_arr().unwrap();
        assert_eq!(windows.len(), 2);
    }

    #[test]
    fn straggler_table_and_histogram() {
        let j = analyze_inputs(&sample_inputs()).unwrap();
        assert_eq!(j.at(&["barriers", "rounds"]).unwrap().as_usize().unwrap(), 2);
        let stragglers = j.at(&["barriers", "stragglers"]).unwrap().as_arr().unwrap();
        assert_eq!(stragglers.len(), 2);
        // node 1 was slowest in round 1, node 0 in round 2
        for s in stragglers {
            assert_eq!(s.at(&["rounds_slowest"]).unwrap().as_usize().unwrap(), 1);
        }
        let per_round = j.at(&["barriers", "per_round"]).unwrap().as_arr().unwrap();
        assert_eq!(
            per_round[0].at(&["straggler", "node"]).unwrap().as_usize().unwrap(),
            1
        );
        let hist: usize = j
            .at(&["barriers", "lag_histogram"])
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|b| b.at(&["count"]).unwrap().as_usize().unwrap())
            .sum();
        assert_eq!(hist, 4, "every ready_lag lands in exactly one bucket");
    }

    #[test]
    fn bandwidth_and_drift_views() {
        let j = analyze_inputs(&sample_inputs()).unwrap();
        assert_eq!(
            j.at(&["bandwidth", "gossip_bytes_total"]).unwrap().as_usize().unwrap(),
            2048
        );
        assert_eq!(
            j.at(&["bandwidth", "merge_bytes_total"]).unwrap().as_usize().unwrap(),
            8192
        );
        let events = j.at(&["drift", "events"]).unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].at(&["node"]).unwrap().as_usize().unwrap(), 0);
        // γ rose from the 0.5 base to 0.8 on the drift tick → boost visible
        assert!(events[0].at(&["boosted"]).unwrap().as_bool().unwrap());
        assert_eq!(j.at(&["drift", "total"]).unwrap().as_usize().unwrap(), 1);
    }

    #[test]
    fn v1_journals_still_analyze() {
        let v1 = tick_line(1, 0, 0, 0, 10, 5, &[], 0, 0.5, None);
        let j = analyze_inputs(&[("old.jsonl".into(), v1)]).unwrap();
        let arms = j.at(&["arms", "totals"]).unwrap().as_obj().unwrap();
        assert!(arms.contains_key(IMPLICIT_ARM), "weightless ticks get the implicit arm");
        assert_eq!(j.at(&["rounds"]).unwrap().as_usize().unwrap(), 0);
    }

    #[test]
    fn invalid_lines_abort_with_location() {
        let err = analyze_inputs(&[("bad.jsonl".into(), "not json".into())]).unwrap_err();
        assert!(err.to_string().contains("bad.jsonl:1"), "{err}");
        let future = "{\"v\":9,\"kind\":\"gossip\",\"tick\":0,\"round\":0,\"bytes\":0}";
        let err = analyze_inputs(&[("f.jsonl".into(), future.into())]).unwrap_err();
        assert!(err.to_string().contains("schema version"), "{err}");
        assert!(analyze_inputs(&[]).is_err());
        assert!(analyze_inputs(&[("empty.jsonl".into(), "\n\n".into())]).is_err());
    }

    fn kernel_tick_line(node: usize, tick: u64, round: u64, kernels: &[(&str, f64)]) -> String {
        Json::obj(vec![
            ("v", Json::from(3usize)),
            ("kind", Json::from("tick")),
            ("tick", Json::from(tick as usize)),
            ("node", Json::from(node)),
            ("round", Json::from(round as usize)),
            ("gamma", Json::from(0.5)),
            ("arrivals", Json::from(10usize)),
            ("trained", Json::from(5usize)),
            ("replayed", Json::from(0usize)),
            ("forward", Json::from(10usize)),
            ("drift", Json::from(0usize)),
            ("weights", Json::obj(vec![])),
            (
                "store",
                Json::obj(vec![
                    ("live", Json::from(1usize)),
                    ("capacity", Json::from(64usize)),
                    ("hits", Json::from(0usize)),
                    ("misses", Json::from(0usize)),
                    ("evictions", Json::from(0usize)),
                ]),
            ),
            (
                "phases",
                Json::Obj(
                    kernels
                        .iter()
                        .map(|(k, s)| (format!("kernel:{k}"), Json::from(*s)))
                        .collect(),
                ),
            ),
        ])
        .to_string()
    }

    #[test]
    fn alert_timeline_tracks_transitions_and_unresolved() {
        let journal = [
            trace::alert_line("straggler_ready_lag", "firing", 3, 48, Some(2), 0.5, 0.15),
            trace::alert_line("straggler_ready_lag", "resolved", 5, 80, Some(2), 0.01, 0.15),
            trace::alert_line("loss_blowup", "firing", 6, 96, None, f64::NAN, 1e6),
        ]
        .join("\n");
        let j = analyze_inputs(&[("trace.jsonl".into(), journal)]).unwrap();
        let events = j.at(&["alerts", "events"]).unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 3);
        assert_eq!(
            events[0].at(&["rule"]).unwrap().as_str().unwrap(),
            "straggler_ready_lag"
        );
        assert_eq!(events[0].at(&["node"]).unwrap().as_usize().unwrap(), 2);
        assert_eq!(events[0].at(&["state"]).unwrap().as_str().unwrap(), "firing");
        // NaN alert values serialize (and re-analyze) as null
        assert!(matches!(*events[2].at(&["value"]).unwrap(), Json::Null));
        assert_eq!(
            j.at(&["alerts", "firing_total", "straggler_ready_lag"])
                .unwrap()
                .as_usize()
                .unwrap(),
            1
        );
        // the straggler resolved; only loss_blowup is still firing at end
        let unresolved = j.at(&["alerts", "unresolved"]).unwrap().as_arr().unwrap();
        assert_eq!(unresolved.len(), 1);
        assert_eq!(
            unresolved[0].at(&["rule"]).unwrap().as_str().unwrap(),
            "loss_blowup"
        );
        let text = render_summary(&j);
        assert!(text.contains("alerts: 3 transition(s), 1 unresolved"), "{text}");
    }

    #[test]
    fn kernel_quantiles_rebuild_from_phases() {
        let mut lines = Vec::new();
        for tick in 0..100u64 {
            // per-tick seconds 0.001..=0.100 → p50 = 0.050, p99 = 0.099
            let secs = (tick + 1) as f64 / 1000.0;
            lines.push(kernel_tick_line(0, tick, 0, &[("sgd_step", secs), ("eval", 2e-7)]));
        }
        let j = analyze_inputs(&[("trace.jsonl".into(), lines.join("\n"))]).unwrap();
        let sgd = j.at(&["kernels", "sgd_step"]).unwrap();
        assert_eq!(sgd.at(&["ticks"]).unwrap().as_usize().unwrap(), 100);
        let p50 = sgd.at(&["p50_seconds"]).unwrap().as_f64().unwrap();
        let p99 = sgd.at(&["p99_seconds"]).unwrap().as_f64().unwrap();
        assert!((p50 - 0.050).abs() < 1e-9, "p50 = {p50}");
        assert!((p99 - 0.099).abs() < 1e-9, "p99 = {p99}");
        // sub-microsecond kernels keep nanosecond resolution
        let eval_p50 = j
            .at(&["kernels", "eval", "p50_seconds"])
            .unwrap()
            .as_f64()
            .unwrap();
        assert!((eval_p50 - 2e-7).abs() < 1e-12, "eval p50 = {eval_p50}");
        let text = render_summary(&j);
        assert!(text.contains("sgd_step"), "{text}");
    }

    #[test]
    fn summary_renders_key_facts() {
        let j = analyze_inputs(&sample_inputs()).unwrap();
        let text = render_summary(&j);
        assert!(text.contains("2 round(s)"), "{text}");
        assert!(text.contains("gossip 2048 B"), "{text}");
        assert!(text.contains("drift: 1 event(s)"), "{text}");
        assert!(text.contains('a') && text.contains('b'));
    }
}

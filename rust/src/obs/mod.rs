//! Unified telemetry layer: a process-wide metrics [`registry`], the
//! per-tick JSONL [`trace`] journal (`--trace PATH`), the scrapeable
//! [`status`] endpoint (`--status-addr ADDR`, `/metrics` + `/status` +
//! `/profile`), the [`health`] rule engine (`--health off|warn|strict`),
//! the always-on [`flight`] crash recorder, and [`prof`] per-kernel
//! continuous profiling.
//!
//! Everything here is strictly *observational*: handles read training
//! state after it is computed and never feed anything back, so enabling
//! telemetry cannot change a selection digest (pinned by e2e tests).

pub mod analyze;
pub mod flight;
pub mod health;
pub mod prof;
pub mod registry;
pub mod status;
pub mod trace;

use std::collections::BTreeMap;
use std::sync::Arc;

pub use health::{HealthEngine, HealthInputs, HealthMode};
pub use registry::{registry, series, Counter, Gauge, Histogram, Registry};
pub use status::StatusServer;
pub use trace::{TraceHandle, TraceJournal};

use crate::util::timer::PhaseTimer;
use trace::{PhaseDelta, TickEvent};

/// Seconds since the registry was first touched in this process.
pub fn uptime_seconds() -> f64 {
    registry().uptime_seconds()
}

/// Everything one processed tick reports, assembled by the trainer after
/// the tick's work (and digest) are final. Counter-like fields that the
/// engine keeps cumulatively are passed cumulative; the observer
/// differences them.
pub struct TickSample<'a> {
    pub tick: u64,
    /// Barrier round this tick ran under (0 for stream runs).
    pub round: u64,
    /// Effective γ this tick (drift boosts included).
    pub gamma: f32,
    pub arrivals: usize,
    pub trained: usize,
    pub replayed: usize,
    /// Cumulative candidate rows forward-scored.
    pub forward_total: u64,
    /// Cumulative drift-detector fires.
    pub drift_total: u64,
    /// `(arm id, weight)` pairs for bandit policies.
    pub weights: Option<Vec<(String, f32)>>,
    pub store_live: usize,
    pub store_capacity: usize,
    pub store_hits: u64,
    pub store_misses: u64,
    pub store_evictions: u64,
    /// `(rolling_loss, rolling_acc)` on prequential-eval ticks.
    pub rolling: Option<(f32, f32)>,
    /// The run's cumulative phase accounting.
    pub phases: &'a PhaseTimer,
}

/// Per-run bundle of registry handles plus the optional trace emitter.
///
/// Handles are resolved once at construction (or on first sight of an
/// arm/phase label) so the per-tick path is pure atomic stores — the
/// registry mutex is off the hot loop.
pub struct TickObserver {
    node: Option<usize>,
    trace: Option<TraceHandle>,
    phase_delta: PhaseDelta,
    prev_forward: u64,
    prev_drift: u64,
    ticks: Arc<Counter>,
    seen: Arc<Counter>,
    trained: Arc<Counter>,
    replayed: Arc<Counter>,
    forward: Arc<Counter>,
    drift: Arc<Counter>,
    gamma: Arc<Gauge>,
    rolling_loss: Arc<Gauge>,
    rolling_acc: Arc<Gauge>,
    store_live: Arc<Gauge>,
    store_capacity: Arc<Gauge>,
    store_pressure: Arc<Gauge>,
    store_hits: Arc<Gauge>,
    store_misses: Arc<Gauge>,
    store_evictions: Arc<Gauge>,
    trained_rows: Arc<Histogram>,
    arm_gauges: BTreeMap<String, Arc<Gauge>>,
    phase_gauges: BTreeMap<&'static str, Arc<Gauge>>,
}

impl TickObserver {
    /// `node = None` for single-process stream/batch runs (unlabelled
    /// series); `Some(i)` labels every series `{node="i"}` so concurrent
    /// cluster nodes stay distinct.
    pub fn new(node: Option<usize>, trace: Option<TraceHandle>) -> TickObserver {
        let name = |base: &str| match node {
            Some(n) => series(base, &[("node", &n.to_string())]),
            None => base.to_string(),
        };
        let r = registry();
        TickObserver {
            node,
            trace,
            phase_delta: PhaseDelta::default(),
            prev_forward: 0,
            prev_drift: 0,
            ticks: r.counter(&name("adaselection_ticks_total")),
            seen: r.counter(&name("adaselection_samples_seen_total")),
            trained: r.counter(&name("adaselection_samples_trained_total")),
            replayed: r.counter(&name("adaselection_samples_replayed_total")),
            forward: r.counter(&name("adaselection_samples_forward_total")),
            drift: r.counter(&name("adaselection_drift_detections_total")),
            gamma: r.gauge(&name("adaselection_effective_gamma")),
            rolling_loss: r.gauge(&name("adaselection_rolling_loss")),
            rolling_acc: r.gauge(&name("adaselection_rolling_acc")),
            store_live: r.gauge(&name("adaselection_store_live")),
            store_capacity: r.gauge(&name("adaselection_store_capacity")),
            store_pressure: r.gauge(&name("adaselection_store_pressure")),
            store_hits: r.gauge(&name("adaselection_store_hits")),
            store_misses: r.gauge(&name("adaselection_store_misses")),
            store_evictions: r.gauge(&name("adaselection_store_evictions")),
            trained_rows: r.histogram(
                &name("adaselection_tick_trained_rows"),
                &[8.0, 16.0, 32.0, 64.0, 128.0, 256.0],
            ),
            arm_gauges: BTreeMap::new(),
            phase_gauges: BTreeMap::new(),
        }
    }

    fn labelled(&self, base: &str, key: &'static str, value: &str) -> String {
        match self.node {
            Some(n) => series(base, &[("node", &n.to_string()), (key, value)]),
            None => series(base, &[(key, value)]),
        }
    }

    /// Record one processed tick: update the registry, feed the flight
    /// ring, and, when tracing, enqueue the journal line.
    pub fn observe(&mut self, s: TickSample<'_>) {
        self.ticks.inc();
        self.seen.add(s.arrivals as u64);
        self.trained.add(s.trained as u64);
        self.replayed.add(s.replayed as u64);
        self.forward.add(s.forward_total.saturating_sub(self.prev_forward));
        self.drift.add(s.drift_total.saturating_sub(self.prev_drift));
        let forward_this_tick = s.forward_total.saturating_sub(self.prev_forward);
        self.prev_forward = s.forward_total;
        self.prev_drift = s.drift_total;
        self.gamma.set(s.gamma as f64);
        self.store_live.set(s.store_live as f64);
        self.store_capacity.set(s.store_capacity as f64);
        self.store_pressure.set(if s.store_capacity > 0 {
            s.store_live as f64 / s.store_capacity as f64
        } else {
            0.0
        });
        self.store_hits.set(s.store_hits as f64);
        self.store_misses.set(s.store_misses as f64);
        self.store_evictions.set(s.store_evictions as f64);
        self.trained_rows.observe(s.trained as f64);
        if let Some((loss, acc)) = s.rolling {
            self.rolling_loss.set(loss as f64);
            if !acc.is_nan() {
                self.rolling_acc.set(acc as f64);
            }
        }
        if let Some(weights) = &s.weights {
            for (arm, w) in weights {
                if !self.arm_gauges.contains_key(arm) {
                    let g = registry()
                        .gauge(&self.labelled("adaselection_arm_weight", "arm", arm));
                    self.arm_gauges.insert(arm.clone(), g);
                }
                self.arm_gauges[arm].set(*w as f64);
            }
        }
        for (phase, total) in s.phases.phases() {
            let g = self.phase_gauges.entry(phase).or_insert_with(|| {
                registry().gauge(&self.labelled("adaselection_phase_seconds", "phase", phase))
            });
            g.set(total.as_secs_f64());
        }
        // the line is built whether or not tracing is on: the flight
        // ring keeps the journal tail for post-mortems regardless
        let mut phases = self.phase_delta.delta(s.phases);
        // per-kernel sub-phase seconds measured inside the backend this
        // tick, drained from this node's thread (`kernel:<name>` keys)
        phases.extend(prof::take_tick_deltas());
        phases.sort_by(|a, b| a.0.cmp(&b.0));
        let empty: Vec<(String, f32)> = Vec::new();
        let line = TickEvent {
            tick: s.tick,
            node: self.node.unwrap_or(0),
            round: s.round,
            gamma: s.gamma,
            arrivals: s.arrivals,
            trained: s.trained,
            replayed: s.replayed,
            forward: forward_this_tick,
            drift: s.drift_total,
            weights: s.weights.as_deref().unwrap_or(&empty),
            store_live: s.store_live,
            store_capacity: s.store_capacity,
            store_hits: s.store_hits,
            store_misses: s.store_misses,
            store_evictions: s.store_evictions,
            phases: &phases,
            rolling: s.rolling,
        }
        .to_line();
        if let Some(trace) = &self.trace {
            flight::record(line.clone());
            trace.emit(line);
        } else {
            flight::record(line);
        }
    }
}

/// Route one already-serialized journal line to the flight ring and,
/// when tracing, the journal — the single choke point that keeps the
/// two byte-identical.
pub fn emit_journal(trace: Option<&TraceHandle>, line: String) {
    if let Some(t) = trace {
        flight::record(line.clone());
        t.emit(line);
    } else {
        flight::record(line);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn observer_updates_registry_and_journal() {
        let dir = std::env::temp_dir().join(format!("ada_obs_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("obs.jsonl");
        let journal = TraceJournal::open(&path).unwrap();
        let mut obs = TickObserver::new(Some(91), Some(journal.handle()));
        let mut phases = PhaseTimer::default();
        for tick in 0..3u64 {
            phases.add("forward", Duration::from_millis(1));
            obs.observe(TickSample {
                tick,
                round: tick / 2,
                gamma: 0.5,
                arrivals: 128,
                trained: 64,
                replayed: 0,
                forward_total: (tick + 1) * 64,
                drift_total: 0,
                weights: Some(vec![("big_loss".into(), 0.6), ("uniform".into(), 0.4)]),
                store_live: 10,
                store_capacity: 100,
                store_hits: 1,
                store_misses: 9,
                store_evictions: 0,
                rolling: Some((1.0, 0.5)),
                phases: &phases,
            });
        }
        drop(obs);
        assert_eq!(journal.finish().unwrap(), 0);

        let r = registry();
        assert_eq!(r.counter("adaselection_ticks_total{node=\"91\"}").get(), 3);
        assert_eq!(r.counter("adaselection_samples_seen_total{node=\"91\"}").get(), 3 * 128);
        // forward was differenced from the cumulative engine counter
        assert_eq!(r.counter("adaselection_samples_forward_total{node=\"91\"}").get(), 3 * 64);
        assert_eq!(r.gauge("adaselection_store_pressure{node=\"91\"}").get(), 0.1);
        assert_eq!(
            r.gauge("adaselection_arm_weight{node=\"91\",arm=\"big_loss\"}").get(),
            0.6
        );
        assert!(r.gauge("adaselection_phase_seconds{node=\"91\",phase=\"forward\"}").get() > 0.0);

        let text = std::fs::read_to_string(&path).unwrap();
        let mut expect = 0u64;
        for line in text.lines() {
            let ev = trace::validate_line(line).unwrap();
            assert_eq!(ev.kind, "tick");
            assert_eq!(ev.node, Some(91));
            assert_eq!(ev.tick, expect, "journal not tick-contiguous");
            assert_eq!(ev.round, expect / 2, "round not echoed into the line");
            expect += 1;
        }
        assert_eq!(expect, 3);
        std::fs::remove_file(&path).ok();
    }
}

//! Continuous per-kernel profiling: sub-phase timers inside the native
//! backend's hot paths (per-sample loss kernels, the fused AdaSelection
//! scorer, the SGD step, eval) aggregated into streaming p50/p95/p99
//! digests per kernel.
//!
//! Two sinks per recorded duration:
//!
//!   * a process-wide [`Histogram`] per kernel (log-spaced duration
//!     buckets) backing the `/profile` endpoint and the
//!     `adaselection_kernel_seconds{kernel=...}` series on `/metrics`;
//!   * a thread-local per-tick accumulator the [`super::TickObserver`]
//!     drains into the journal's `phases` object as `kernel:<name>`
//!     entries — each cluster node ticks on its own thread, so the
//!     thread-local keeps per-node attribution exact and
//!     `trace-analyze` can rebuild per-kernel quantiles offline.
//!
//! Timing only *reads* the clock around already-scheduled work, so the
//! digest-parity e2es hold with profiling on (it is always on).

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::util::json::Json;

use super::registry::{registry, series, Histogram};

/// Finite bucket bounds in seconds: 1µs · 2^k for k = 0..20 (≈1µs to
/// ≈1s); slower calls land in the +Inf bucket and clamp to the last
/// bound in quantile estimates.
fn duration_bounds() -> &'static [f64] {
    static BOUNDS: OnceLock<Vec<f64>> = OnceLock::new();
    BOUNDS.get_or_init(|| (0..21).map(|k| 1e-6 * f64::powi(2.0, k)).collect())
}

fn kernels() -> &'static Mutex<BTreeMap<&'static str, Arc<Histogram>>> {
    static KERNELS: OnceLock<Mutex<BTreeMap<&'static str, Arc<Histogram>>>> = OnceLock::new();
    KERNELS.get_or_init(|| Mutex::new(BTreeMap::new()))
}

thread_local! {
    /// Seconds per kernel accumulated on this thread since the last
    /// [`take_tick_deltas`] — exactly one tick's worth in steady state.
    static TICK_ACC: RefCell<BTreeMap<&'static str, f64>> = RefCell::new(BTreeMap::new());
}

/// Record one kernel invocation.
pub fn record(kernel: &'static str, elapsed: Duration) {
    let secs = elapsed.as_secs_f64();
    let hist = {
        let mut m = kernels().lock().unwrap_or_else(|p| p.into_inner());
        Arc::clone(m.entry(kernel).or_insert_with(|| {
            registry().histogram(
                &series("adaselection_kernel_seconds", &[("kernel", kernel)]),
                duration_bounds(),
            )
        }))
    };
    hist.observe(secs);
    TICK_ACC.with(|acc| {
        *acc.borrow_mut().entry(kernel).or_insert(0.0) += secs;
    });
}

/// Time `f` under `kernel`.
pub fn time<T>(kernel: &'static str, f: impl FnOnce() -> T) -> T {
    let start = Instant::now();
    let out = f();
    record(kernel, start.elapsed());
    out
}

/// Drain this thread's per-tick kernel seconds as journal phase entries
/// (`kernel:<name>` → seconds), alphabetical. Empty off the native
/// backend's threads.
pub fn take_tick_deltas() -> Vec<(String, f64)> {
    TICK_ACC.with(|acc| {
        let mut m = acc.borrow_mut();
        let out = m.iter().map(|(k, s)| (format!("kernel:{k}"), *s)).collect();
        m.clear();
        out
    })
}

/// One kernel's streaming digest.
#[derive(Debug, Clone)]
pub struct KernelStats {
    pub kernel: &'static str,
    pub count: u64,
    pub total_seconds: f64,
    pub p50_seconds: f64,
    pub p95_seconds: f64,
    pub p99_seconds: f64,
}

/// Every kernel's digest, alphabetical by kernel name.
pub fn kernel_stats() -> Vec<KernelStats> {
    let m = kernels().lock().unwrap_or_else(|p| p.into_inner());
    m.iter()
        .map(|(&kernel, h)| KernelStats {
            kernel,
            count: h.count(),
            total_seconds: h.sum(),
            p50_seconds: h.quantile(0.50),
            p95_seconds: h.quantile(0.95),
            p99_seconds: h.quantile(0.99),
        })
        .collect()
}

/// The `/profile` document.
pub fn profile_json() -> Json {
    fn num(v: f64) -> Json {
        if v.is_finite() { Json::from(v) } else { Json::Null }
    }
    let mut per_kernel: BTreeMap<String, Json> = BTreeMap::new();
    for s in kernel_stats() {
        per_kernel.insert(
            s.kernel.to_string(),
            Json::obj(vec![
                ("count", Json::from(s.count as usize)),
                ("total_seconds", num(s.total_seconds)),
                ("p50_seconds", num(s.p50_seconds)),
                ("p95_seconds", num(s.p95_seconds)),
                ("p99_seconds", num(s.p99_seconds)),
            ]),
        );
    }
    Json::obj(vec![
        ("uptime_seconds", Json::from(super::uptime_seconds())),
        ("kernels", Json::Obj(per_kernel)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_aggregate_and_drain_per_tick() {
        time("test_kernel_a", || std::thread::sleep(Duration::from_micros(200)));
        record("test_kernel_a", Duration::from_micros(100));
        record("test_kernel_b", Duration::from_millis(2));

        let stats = kernel_stats();
        let a = stats.iter().find(|s| s.kernel == "test_kernel_a").unwrap();
        assert!(a.count >= 2);
        assert!(a.total_seconds > 0.0);
        assert!(a.p50_seconds > 0.0 && a.p99_seconds >= a.p50_seconds);

        let deltas = take_tick_deltas();
        let names: Vec<&str> = deltas.iter().map(|(n, _)| n.as_str()).collect();
        assert!(names.contains(&"kernel:test_kernel_a"));
        assert!(names.contains(&"kernel:test_kernel_b"));
        for (_, secs) in &deltas {
            assert!(*secs > 0.0);
        }
        // drained: the next tick starts from zero
        assert!(take_tick_deltas()
            .iter()
            .all(|(n, _)| !n.starts_with("kernel:test_kernel_")));

        let j = profile_json();
        assert!(j.at(&["kernels", "test_kernel_a", "count"]).unwrap().as_f64().unwrap() >= 2.0);
    }
}

//! Fleet health alerting: a declarative rule engine evaluated from the
//! metrics-registry snapshot each tick (stream) or barrier round
//! (cluster).
//!
//! Built-in rules (all thresholds in [`Thresholds`]):
//!
//! | rule                    | fires when                                        |
//! |-------------------------|---------------------------------------------------|
//! | `straggler_ready_lag`   | a node's barrier ready-lag exceeds `factor` × the fleet median (and an absolute floor) |
//! | `heartbeat_stale`       | an alive node's last heartbeat is older than `heartbeat_stale_seconds` |
//! | `store_eviction_pressure` | the store is evicting while pressure ≥ `store_pressure_max` |
//! | `trace_dropped_lines`   | the trace journal dropped lines since the last evaluation |
//! | `arrival_rate_stall`    | no new arrivals for `stall_evals` consecutive evaluations |
//! | `rolling_loss_blowup`   | the rolling loss is non-finite or above `loss_blowup` |
//!
//! Each rule runs a firing→resolved state machine per `(rule, node)`:
//! transitions emit `kind:"alert"` journal lines (trace schema v3, also
//! recorded by the flight ring), bump `adaselection_alerts_total{rule}`,
//! and WARN/log. Active alerts are published for the `/status` `alerts`
//! block. `--health strict` turns any *still-firing* alert at run end
//! into a nonzero exit for CI gating; alerts that resolved (e.g. a
//! straggler that was shed) do not fail the run.
//!
//! The engine only reads already-published telemetry, so evaluation is
//! off the digest path — pinned by the zero-interference e2es.

use std::collections::BTreeMap;
use std::sync::{Mutex, OnceLock};

use crate::util::json::Json;

use super::flight;
use super::registry::{registry, series};
use super::trace::{alert_line, TraceHandle};

/// `--health` knob.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HealthMode {
    /// No evaluation at all (default).
    Off,
    /// Evaluate + alert, never fail the run.
    Warn,
    /// Like `warn`, but any alert still firing at run end exits nonzero.
    Strict,
}

impl HealthMode {
    pub fn parse(s: &str) -> anyhow::Result<HealthMode> {
        match s {
            "off" => Ok(HealthMode::Off),
            "warn" => Ok(HealthMode::Warn),
            "strict" => Ok(HealthMode::Strict),
            other => anyhow::bail!("--health must be off|warn|strict (got '{other}')"),
        }
    }

    pub fn is_off(&self) -> bool {
        matches!(self, HealthMode::Off)
    }
}

/// Rule thresholds; the defaults are deliberately conservative so a
/// healthy run stays silent.
#[derive(Clone, Debug)]
pub struct Thresholds {
    /// A node is a straggler above `factor × fleet-median ready lag`...
    pub straggler_lag_factor: f64,
    /// ...but never below this absolute floor (scheduler noise).
    pub straggler_lag_min_seconds: f64,
    pub heartbeat_stale_seconds: f64,
    /// Store pressure (live/capacity) at or above this while evicting.
    pub store_pressure_max: f64,
    /// Rolling loss above this counts as blown up even while finite.
    pub loss_blowup: f64,
    /// Consecutive zero-arrival evaluations before a stall fires.
    pub stall_evals: u32,
}

impl Default for Thresholds {
    fn default() -> Thresholds {
        Thresholds {
            straggler_lag_factor: 3.0,
            straggler_lag_min_seconds: 0.05,
            heartbeat_stale_seconds: 5.0,
            store_pressure_max: 0.9,
            loss_blowup: 1e6,
            stall_evals: 3,
        }
    }
}

/// One active (firing) alert.
#[derive(Clone, Debug)]
pub struct ActiveAlert {
    pub rule: &'static str,
    pub node: Option<usize>,
    pub value: f64,
    pub threshold: f64,
    pub since_round: u64,
    pub since_tick: u64,
}

/// What one evaluation reads. Built from the live registry via
/// [`HealthInputs::from_registry`]; tests hand-roll snapshots.
pub struct HealthInputs {
    /// Flat registry snapshot (`Registry::snapshot` shape).
    pub snapshot: Vec<(String, f64)>,
    /// Registry uptime at snapshot time (heartbeat ages subtract it).
    pub uptime: f64,
    /// The *raw* rolling loss — passed explicitly because the gauge is
    /// only written when finite, which would hide exactly the non-finite
    /// case this rule exists for.
    pub rolling_loss: Option<f64>,
}

impl HealthInputs {
    pub fn from_registry(rolling_loss: Option<f64>) -> HealthInputs {
        HealthInputs {
            snapshot: registry().snapshot(),
            uptime: registry().uptime_seconds(),
            rolling_loss,
        }
    }
}

/// Process-wide view of currently-firing alerts, for `/status`.
static ACTIVE: OnceLock<Mutex<Vec<ActiveAlert>>> = OnceLock::new();

fn active_slot() -> &'static Mutex<Vec<ActiveAlert>> {
    ACTIVE.get_or_init(|| Mutex::new(Vec::new()))
}

/// Currently-firing alerts (most recent evaluation).
pub fn active_alerts() -> Vec<ActiveAlert> {
    active_slot().lock().unwrap_or_else(|p| p.into_inner()).clone()
}

/// The `/status` `alerts` block.
pub fn alerts_json() -> Json {
    fn num(v: f64) -> Json {
        if v.is_finite() { Json::from(v) } else { Json::Null }
    }
    let active = active_alerts();
    let rows: Vec<Json> = active
        .iter()
        .map(|a| {
            let mut pairs = vec![("rule", Json::from(a.rule))];
            if let Some(n) = a.node {
                pairs.push(("node", Json::from(n)));
            }
            pairs.push(("value", num(a.value)));
            pairs.push(("threshold", num(a.threshold)));
            pairs.push(("since_round", Json::from(a.since_round as usize)));
            pairs.push(("since_tick", Json::from(a.since_tick as usize)));
            Json::obj(pairs)
        })
        .collect();
    Json::obj(vec![
        ("firing", Json::from(active.len())),
        ("active", Json::Arr(rows)),
    ])
}

/// A rule violation observed by one evaluation pass.
struct Violation {
    rule: &'static str,
    node: Option<usize>,
    value: f64,
    threshold: f64,
}

/// The rule engine: one per run, owned by the trainer/coordinator.
pub struct HealthEngine {
    mode: HealthMode,
    thresholds: Thresholds,
    trace: Option<TraceHandle>,
    active: BTreeMap<(&'static str, Option<usize>), ActiveAlert>,
    prev_dropped: f64,
    prev_evictions: f64,
    prev_arrivals: f64,
    zero_arrival_evals: u32,
    evals: u64,
}

impl HealthEngine {
    pub fn new(mode: HealthMode) -> HealthEngine {
        HealthEngine {
            mode,
            thresholds: Thresholds::default(),
            trace: None,
            active: BTreeMap::new(),
            prev_dropped: 0.0,
            prev_evictions: 0.0,
            prev_arrivals: 0.0,
            zero_arrival_evals: 0,
            evals: 0,
        }
    }

    /// Alert transitions also land in the journal when tracing.
    pub fn attach_trace(&mut self, trace: Option<TraceHandle>) {
        self.trace = trace;
    }

    pub fn mode(&self) -> HealthMode {
        self.mode
    }

    /// Evaluate every rule against `inputs`; emit firing/resolved
    /// transitions. No-op when the mode is `off`.
    pub fn evaluate(&mut self, round: u64, tick: u64, inputs: &HealthInputs) {
        if self.mode.is_off() {
            return;
        }
        self.evals += 1;
        let mut violations = Vec::new();
        self.rule_straggler(inputs, &mut violations);
        self.rule_heartbeat(inputs, &mut violations);
        self.rule_store_pressure(inputs, &mut violations);
        self.rule_trace_drops(inputs, &mut violations);
        self.rule_arrival_stall(inputs, &mut violations);
        self.rule_loss_blowup(inputs, &mut violations);

        // firing→resolved state machine per (rule, node)
        let mut seen: std::collections::BTreeSet<(&'static str, Option<usize>)> =
            Default::default();
        for v in violations {
            let key = (v.rule, v.node);
            seen.insert(key);
            if let Some(a) = self.active.get_mut(&key) {
                a.value = v.value;
                a.threshold = v.threshold;
                continue;
            }
            registry()
                .counter(&series("adaselection_alerts_total", &[("rule", v.rule)]))
                .inc();
            self.emit(v.rule, "firing", round, tick, v.node, v.value, v.threshold);
            log::warn!(
                "health: {} firing{} (value {:.6}, threshold {:.6}) @round {round} tick {tick}",
                v.rule,
                v.node.map(|n| format!(" node {n}")).unwrap_or_default(),
                v.value,
                v.threshold
            );
            self.active.insert(
                key,
                ActiveAlert {
                    rule: v.rule,
                    node: v.node,
                    value: v.value,
                    threshold: v.threshold,
                    since_round: round,
                    since_tick: tick,
                },
            );
        }
        let resolved: Vec<(&'static str, Option<usize>)> =
            self.active.keys().filter(|k| !seen.contains(*k)).copied().collect();
        for key in resolved {
            let a = self.active.remove(&key).expect("key came from the map");
            self.emit(a.rule, "resolved", round, tick, a.node, a.value, a.threshold);
            log::info!(
                "health: {} resolved{} @round {round} tick {tick}",
                a.rule,
                a.node.map(|n| format!(" node {n}")).unwrap_or_default()
            );
        }
        *active_slot().lock().unwrap_or_else(|p| p.into_inner()) =
            self.active.values().cloned().collect();
    }

    fn emit(
        &self,
        rule: &str,
        state: &str,
        round: u64,
        tick: u64,
        node: Option<usize>,
        value: f64,
        threshold: f64,
    ) {
        let line = alert_line(rule, state, round, tick, node, value, threshold);
        if let Some(t) = &self.trace {
            flight::record(line.clone());
            t.emit(line);
        } else {
            flight::record(line);
        }
    }

    fn rule_straggler(&self, inputs: &HealthInputs, out: &mut Vec<Violation>) {
        let lags = alive_node_series(inputs, "adaselection_node_ready_lag_seconds");
        if lags.len() < 2 {
            return;
        }
        let mut sorted: Vec<f64> = lags.iter().map(|&(_, v)| v).collect();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let mid = sorted.len() / 2;
        let median = if sorted.len() % 2 == 0 {
            (sorted[mid - 1] + sorted[mid]) / 2.0
        } else {
            sorted[mid]
        };
        let threshold = (self.thresholds.straggler_lag_factor * median)
            .max(self.thresholds.straggler_lag_min_seconds);
        for (node, lag) in lags {
            if lag > threshold {
                out.push(Violation {
                    rule: "straggler_ready_lag",
                    node: Some(node),
                    value: lag,
                    threshold,
                });
            }
        }
    }

    fn rule_heartbeat(&self, inputs: &HealthInputs, out: &mut Vec<Violation>) {
        for (node, at) in
            alive_node_series(inputs, "adaselection_node_heartbeat_uptime_seconds")
        {
            let age = (inputs.uptime - at).max(0.0);
            if age > self.thresholds.heartbeat_stale_seconds {
                out.push(Violation {
                    rule: "heartbeat_stale",
                    node: Some(node),
                    value: age,
                    threshold: self.thresholds.heartbeat_stale_seconds,
                });
            }
        }
    }

    fn rule_store_pressure(&mut self, inputs: &HealthInputs, out: &mut Vec<Violation>) {
        let value = |name: &str| {
            inputs.snapshot.iter().find(|(n, _)| n == name).map(|&(_, v)| v)
        };
        let evictions = value("adaselection_store_evictions").unwrap_or(0.0);
        let evicting = evictions > self.prev_evictions;
        self.prev_evictions = evictions;
        let Some(pressure) = value("adaselection_store_pressure") else { return };
        if evicting && pressure >= self.thresholds.store_pressure_max {
            out.push(Violation {
                rule: "store_eviction_pressure",
                node: None,
                value: pressure,
                threshold: self.thresholds.store_pressure_max,
            });
        }
    }

    fn rule_trace_drops(&mut self, inputs: &HealthInputs, out: &mut Vec<Violation>) {
        let dropped = inputs
            .snapshot
            .iter()
            .find(|(n, _)| n == "adaselection_trace_dropped_lines_total")
            .map(|&(_, v)| v)
            .unwrap_or(0.0);
        let delta = dropped - self.prev_dropped;
        self.prev_dropped = dropped;
        if delta > 0.0 {
            out.push(Violation {
                rule: "trace_dropped_lines",
                node: None,
                value: delta,
                threshold: 0.0,
            });
        }
    }

    fn rule_arrival_stall(&mut self, inputs: &HealthInputs, out: &mut Vec<Violation>) {
        // sum arrivals across every runtime's spelling: the stream
        // counter (plus node-labelled variants) and the process
        // coordinator's per-node heartbeat gauges
        let arrivals: f64 = inputs
            .snapshot
            .iter()
            .filter(|(n, _)| {
                n.starts_with("adaselection_samples_seen_total")
                    || n.starts_with("adaselection_node_samples_seen")
            })
            .map(|&(_, v)| v)
            .sum();
        let stalled = self.evals > 1 && arrivals <= self.prev_arrivals;
        self.prev_arrivals = arrivals;
        if stalled {
            self.zero_arrival_evals += 1;
        } else {
            self.zero_arrival_evals = 0;
        }
        if self.zero_arrival_evals >= self.thresholds.stall_evals {
            out.push(Violation {
                rule: "arrival_rate_stall",
                node: None,
                value: self.zero_arrival_evals as f64,
                threshold: self.thresholds.stall_evals as f64,
            });
        }
    }

    fn rule_loss_blowup(&self, inputs: &HealthInputs, out: &mut Vec<Violation>) {
        let Some(loss) = inputs.rolling_loss else { return };
        if !loss.is_finite() || loss > self.thresholds.loss_blowup {
            out.push(Violation {
                rule: "rolling_loss_blowup",
                node: None,
                value: loss,
                threshold: self.thresholds.loss_blowup,
            });
        }
    }

    /// Currently-firing alerts.
    pub fn active(&self) -> Vec<ActiveAlert> {
        self.active.values().cloned().collect()
    }

    /// End-of-run gate: in `strict` mode any alert still firing fails
    /// the run (resolved alerts do not).
    pub fn finish(&self) -> anyhow::Result<()> {
        if self.mode != HealthMode::Strict || self.active.is_empty() {
            return Ok(());
        }
        let rules: Vec<String> = self
            .active
            .values()
            .map(|a| match a.node {
                Some(n) => format!("{}(node {n})", a.rule),
                None => a.rule.to_string(),
            })
            .collect();
        anyhow::bail!(
            "health strict: {} alert(s) still firing at run end: {}",
            rules.len(),
            rules.join(", ")
        )
    }
}

/// `(node, value)` pairs for `base{node="i"}` series, restricted to
/// nodes whose `adaselection_node_alive` gauge is 1 (or absent — the
/// single-process stream has no membership gauges).
fn alive_node_series(inputs: &HealthInputs, base: &str) -> Vec<(usize, f64)> {
    let prefix = format!("{base}{{node=\"");
    let mut out = Vec::new();
    for (name, v) in &inputs.snapshot {
        let Some(rest) = name.strip_prefix(&prefix) else { continue };
        let Some(node) = rest.strip_suffix("\"}") else { continue };
        let Ok(node_id) = node.parse::<usize>() else { continue };
        let alive = inputs
            .snapshot
            .iter()
            .find(|(n, _)| n == &format!("adaselection_node_alive{{node=\"{node}\"}}"))
            .map(|&(_, a)| a > 0.0)
            .unwrap_or(true);
        if alive {
            out.push((node_id, *v));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inputs(snapshot: Vec<(&str, f64)>, loss: Option<f64>) -> HealthInputs {
        HealthInputs {
            snapshot: snapshot.into_iter().map(|(n, v)| (n.to_string(), v)).collect(),
            uptime: 100.0,
            rolling_loss: loss,
        }
    }

    #[test]
    fn off_mode_never_evaluates() {
        let mut e = HealthEngine::new(HealthMode::Off);
        e.evaluate(1, 1, &inputs(vec![], Some(f64::NAN)));
        assert!(e.active().is_empty());
        assert!(e.finish().is_ok());
    }

    #[test]
    fn straggler_fires_and_resolves() {
        let mut e = HealthEngine::new(HealthMode::Warn);
        let lag = |n: &str, v: f64| {
            (format!("adaselection_node_ready_lag_seconds{{node=\"{n}\"}}"), v)
        };
        let snap: Vec<(String, f64)> =
            vec![lag("0", 0.01), lag("1", 0.012), lag("2", 0.5), lag("3", 0.011)];
        let inp = HealthInputs { snapshot: snap, uptime: 1.0, rolling_loss: None };
        e.evaluate(1, 8, &inp);
        let active = e.active();
        assert_eq!(active.len(), 1);
        assert_eq!(active[0].rule, "straggler_ready_lag");
        assert_eq!(active[0].node, Some(2));
        // the straggler sheds: its alive gauge goes 0 → alert resolves
        let mut snap2 = inp.snapshot.clone();
        snap2.push(("adaselection_node_alive{node=\"2\"}".to_string(), 0.0));
        e.evaluate(2, 16, &HealthInputs { snapshot: snap2, uptime: 2.0, rolling_loss: None });
        assert!(e.active().is_empty());
        assert!(e.finish().is_ok());
    }

    #[test]
    fn uniform_lags_stay_silent() {
        let mut e = HealthEngine::new(HealthMode::Warn);
        let snap: Vec<(String, f64)> = (0..4)
            .map(|n| {
                (format!("adaselection_node_ready_lag_seconds{{node=\"{n}\"}}"), 0.01)
            })
            .collect();
        e.evaluate(1, 8, &HealthInputs { snapshot: snap, uptime: 1.0, rolling_loss: None });
        assert!(e.active().is_empty());
    }

    #[test]
    fn heartbeat_staleness_respects_liveness() {
        let mut e = HealthEngine::new(HealthMode::Warn);
        let inp = inputs(
            vec![
                ("adaselection_node_heartbeat_uptime_seconds{node=\"0\"}", 99.5),
                ("adaselection_node_heartbeat_uptime_seconds{node=\"1\"}", 10.0),
                ("adaselection_node_heartbeat_uptime_seconds{node=\"2\"}", 10.0),
                ("adaselection_node_alive{node=\"2\"}", 0.0),
            ],
            None,
        );
        e.evaluate(3, 24, &inp);
        let active = e.active();
        // node 1 is stale (age 90s); node 2 is just as old but dead
        assert_eq!(active.len(), 1);
        assert_eq!(active[0].rule, "heartbeat_stale");
        assert_eq!(active[0].node, Some(1));
    }

    #[test]
    fn loss_blowup_and_stall_fire() {
        let mut e = HealthEngine::new(HealthMode::Strict);
        // NaN loss fires immediately
        e.evaluate(1, 1, &inputs(vec![], Some(f64::NAN)));
        assert!(e.active().iter().any(|a| a.rule == "rolling_loss_blowup"));
        assert!(e.finish().is_err());
        // arrivals frozen across stall_evals+1 evaluations → stall fires
        let mut e = HealthEngine::new(HealthMode::Warn);
        for t in 0..5u64 {
            e.evaluate(1, t, &inputs(vec![("adaselection_samples_seen_total", 128.0)], None));
        }
        assert!(e.active().iter().any(|a| a.rule == "arrival_rate_stall"));
        // arrivals move again → resolves
        e.evaluate(1, 6, &inputs(vec![("adaselection_samples_seen_total", 256.0)], None));
        assert!(!e.active().iter().any(|a| a.rule == "arrival_rate_stall"));
    }

    #[test]
    fn store_pressure_requires_active_eviction() {
        let mut e = HealthEngine::new(HealthMode::Warn);
        // high pressure but no evictions yet: silent
        e.evaluate(
            1,
            1,
            &inputs(
                vec![
                    ("adaselection_store_pressure", 0.99),
                    ("adaselection_store_evictions", 0.0),
                ],
                None,
            ),
        );
        assert!(e.active().is_empty());
        // evictions advance under pressure: fires
        e.evaluate(
            1,
            2,
            &inputs(
                vec![
                    ("adaselection_store_pressure", 0.99),
                    ("adaselection_store_evictions", 32.0),
                ],
                None,
            ),
        );
        assert!(e.active().iter().any(|a| a.rule == "store_eviction_pressure"));
    }

    #[test]
    fn trace_drop_delta_fires_once_per_burst() {
        let mut e = HealthEngine::new(HealthMode::Warn);
        e.evaluate(1, 1, &inputs(vec![("adaselection_trace_dropped_lines_total", 0.0)], None));
        assert!(e.active().is_empty());
        e.evaluate(1, 2, &inputs(vec![("adaselection_trace_dropped_lines_total", 7.0)], None));
        assert!(e.active().iter().any(|a| a.rule == "trace_dropped_lines"));
        // no further drops → resolves
        e.evaluate(1, 3, &inputs(vec![("adaselection_trace_dropped_lines_total", 7.0)], None));
        assert!(e.active().is_empty());
    }
}

//! Process-wide metrics registry: monotonic counters, gauges, and
//! fixed-bucket histograms behind cheap atomics.
//!
//! Handles are registered once (a `Mutex<BTreeMap>` guards the name
//! space) and sampled anywhere through `Arc`s — the hot loop never takes
//! the registry lock. Series names carry their labels Prometheus-style
//! (`adaselection_arm_weight{arm="big_loss"}`); two registrations of the
//! same name return the same underlying metric.
//!
//! The registry is process-wide and cumulative: sequential runs in one
//! process share series unless they label them apart (the cluster layer
//! labels per node). Telemetry only *reads* training state, so nothing
//! here can perturb selection — the digest parity e2es pin that.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Monotonic counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge storing an `f64` as its bit pattern.
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Fixed-bucket histogram: cumulative-style bucket counts plus sum/count.
#[derive(Debug)]
pub struct Histogram {
    /// Upper bounds of the finite buckets; an implicit +Inf bucket follows.
    bounds: Vec<f64>,
    counts: Vec<AtomicU64>,
    sum_bits: AtomicU64,
    count: AtomicU64,
}

impl Histogram {
    fn new(bounds: &[f64]) -> Histogram {
        Histogram {
            bounds: bounds.to_vec(),
            counts: (0..=bounds.len()).map(|_| AtomicU64::new(0)).collect(),
            sum_bits: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    pub fn observe(&self, v: f64) {
        let i = self.bounds.iter().position(|&b| v <= b).unwrap_or(self.bounds.len());
        self.counts[i].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        // CAS loop on the f64 bit pattern: contention here is negligible
        // (histograms are sampled per tick, not per row)
        let mut cur = self.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self.sum_bits.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum_bits.load(Ordering::Relaxed))
    }

    /// `(upper_bound, cumulative_count)` pairs ending with the +Inf bucket.
    pub fn cumulative_buckets(&self) -> Vec<(f64, u64)> {
        let mut acc = 0u64;
        let mut out = Vec::with_capacity(self.counts.len());
        for (i, c) in self.counts.iter().enumerate() {
            acc += c.load(Ordering::Relaxed);
            let bound = self.bounds.get(i).copied().unwrap_or(f64::INFINITY);
            out.push((bound, acc));
        }
        out
    }

    /// Estimated quantile (`q` in 0..=1) by linear interpolation inside
    /// the bucket whose cumulative count crosses the target rank —
    /// Prometheus `histogram_quantile` semantics. Observations in the
    /// +Inf bucket clamp to the last finite bound; an empty histogram
    /// returns NaN.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count.load(Ordering::Relaxed);
        if total == 0 {
            return f64::NAN;
        }
        let rank = q.clamp(0.0, 1.0) * total as f64;
        let mut prev_cum = 0u64;
        let mut lower = 0.0f64;
        for (i, c) in self.counts.iter().enumerate() {
            let cum = prev_cum + c.load(Ordering::Relaxed);
            if cum as f64 >= rank && cum > prev_cum {
                let upper = match self.bounds.get(i) {
                    Some(&b) => b,
                    None => return self.bounds.last().copied().unwrap_or(f64::NAN),
                };
                let frac = (rank - prev_cum as f64) / (cum - prev_cum) as f64;
                return lower + (upper - lower) * frac.clamp(0.0, 1.0);
            }
            prev_cum = cum;
            if let Some(&b) = self.bounds.get(i) {
                lower = b;
            }
        }
        self.bounds.last().copied().unwrap_or(f64::NAN)
    }
}

enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

/// The registry proper: a guarded name → metric map.
pub struct Registry {
    metrics: Mutex<BTreeMap<String, Metric>>,
    start: Instant,
}

impl Registry {
    fn new() -> Registry {
        Registry { metrics: Mutex::new(BTreeMap::new()), start: Instant::now() }
    }

    /// Lock the name map, recovering from poisoning: a panic in one
    /// scrape or writer thread must not take `/metrics` down for every
    /// later request. The map holds only `Arc` handles, so a poisoned
    /// guard is still structurally sound.
    fn lock_metrics(&self) -> std::sync::MutexGuard<'_, BTreeMap<String, Metric>> {
        self.metrics.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Seconds since this registry was first touched.
    pub fn uptime_seconds(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Get-or-register a counter under `name` (labels included).
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = self.lock_metrics();
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Counter(Arc::new(Counter::default())))
        {
            Metric::Counter(c) => Arc::clone(c),
            _ => panic!("metric '{name}' already registered with another type"),
        }
    }

    /// Get-or-register a gauge under `name` (labels included).
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = self.lock_metrics();
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Gauge(Arc::new(Gauge::default())))
        {
            Metric::Gauge(g) => Arc::clone(g),
            _ => panic!("metric '{name}' already registered with another type"),
        }
    }

    /// Get-or-register a histogram with the given finite bucket bounds.
    pub fn histogram(&self, name: &str, bounds: &[f64]) -> Arc<Histogram> {
        let mut m = self.lock_metrics();
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Histogram(Arc::new(Histogram::new(bounds))))
        {
            Metric::Histogram(h) => Arc::clone(h),
            _ => panic!("metric '{name}' already registered with another type"),
        }
    }

    /// Flat `(series_name, value)` view (histograms contribute `_sum` and
    /// `_count` series). Used to assemble `/status`.
    pub fn snapshot(&self) -> Vec<(String, f64)> {
        let m = self.lock_metrics();
        let mut out = Vec::with_capacity(m.len());
        for (name, metric) in m.iter() {
            match metric {
                Metric::Counter(c) => out.push((name.clone(), c.get() as f64)),
                Metric::Gauge(g) => out.push((name.clone(), g.get())),
                Metric::Histogram(h) => {
                    out.push((hist_series(name, "_sum"), h.sum()));
                    out.push((hist_series(name, "_count"), h.count() as f64));
                    out.push((hist_series(name, "_p50"), h.quantile(0.50)));
                    out.push((hist_series(name, "_p95"), h.quantile(0.95)));
                    out.push((hist_series(name, "_p99"), h.quantile(0.99)));
                }
            }
        }
        out
    }

    /// Prometheus text exposition (format 0.0.4) of every registered series.
    pub fn render_prometheus(&self) -> String {
        let m = self.lock_metrics();
        let mut out = String::new();
        let mut last_family = String::new();
        for (name, metric) in m.iter() {
            let family = name.split('{').next().unwrap_or(name);
            if family != last_family {
                let kind = match metric {
                    Metric::Counter(_) => "counter",
                    Metric::Gauge(_) => "gauge",
                    Metric::Histogram(_) => "histogram",
                };
                out.push_str(&format!("# TYPE {family} {kind}\n"));
                last_family = family.to_string();
            }
            match metric {
                Metric::Counter(c) => out.push_str(&format!("{name} {}\n", c.get())),
                Metric::Gauge(g) => out.push_str(&format!("{name} {}\n", fmt_f64(g.get()))),
                Metric::Histogram(h) => {
                    for (bound, cum) in h.cumulative_buckets() {
                        let le = if bound.is_infinite() {
                            "+Inf".to_string()
                        } else {
                            fmt_f64(bound)
                        };
                        out.push_str(&format!(
                            "{} {cum}\n",
                            with_label(name, "le", &le, "_bucket")
                        ));
                    }
                    out.push_str(&format!("{} {}\n", hist_series(name, "_sum"), fmt_f64(h.sum())));
                    out.push_str(&format!("{} {}\n", hist_series(name, "_count"), h.count()));
                    for (suffix, q) in [("_p50", 0.50), ("_p95", 0.95), ("_p99", 0.99)] {
                        out.push_str(&format!(
                            "{} {}\n",
                            hist_series(name, suffix),
                            fmt_f64(h.quantile(q))
                        ));
                    }
                }
            }
        }
        out
    }
}

/// `name{labels}` + suffix → `name<suffix>{labels}`.
fn hist_series(name: &str, suffix: &str) -> String {
    match name.split_once('{') {
        Some((base, rest)) => format!("{base}{suffix}{{{rest}"),
        None => format!("{name}{suffix}"),
    }
}

/// Append one more label to a possibly-already-labelled series name.
fn with_label(name: &str, key: &str, value: &str, suffix: &str) -> String {
    match name.split_once('{') {
        Some((base, rest)) => {
            let rest = rest.trim_end_matches('}');
            format!("{base}{suffix}{{{rest},{key}=\"{value}\"}}")
        }
        None => format!("{name}{suffix}{{{key}=\"{value}\"}}"),
    }
}

fn fmt_f64(v: f64) -> String {
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{v:.0}")
    } else {
        format!("{v}")
    }
}

/// Build a labelled series name: `series("x", &[("a","1")])` → `x{a="1"}`.
pub fn series(name: &str, labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return name.to_string();
    }
    let body: Vec<String> =
        labels.iter().map(|(k, v)| format!("{k}=\"{v}\"")).collect();
    format!("{name}{{{}}}", body.join(","))
}

static REGISTRY: OnceLock<Registry> = OnceLock::new();

/// The process-wide registry.
pub fn registry() -> &'static Registry {
    REGISTRY.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_round_trip() {
        let r = Registry::new();
        let c = r.counter("t_total");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        // re-registration returns the same metric
        assert_eq!(r.counter("t_total").get(), 5);
        let g = r.gauge(&series("t_gamma", &[("node", "3")]));
        g.set(0.75);
        assert_eq!(r.gauge("t_gamma{node=\"3\"}").get(), 0.75);
    }

    #[test]
    fn histogram_buckets_are_cumulative() {
        let r = Registry::new();
        let h = r.histogram("t_lat", &[1.0, 10.0]);
        for v in [0.5, 0.7, 5.0, 50.0] {
            h.observe(v);
        }
        assert_eq!(h.count(), 4);
        assert!((h.sum() - 56.2).abs() < 1e-9);
        assert_eq!(
            h.cumulative_buckets(),
            vec![(1.0, 2), (10.0, 3), (f64::INFINITY, 4)]
        );
    }

    #[test]
    fn prometheus_rendering_is_parseable_lines() {
        let r = Registry::new();
        r.counter("t_ticks_total").add(7);
        r.gauge(&series("t_w", &[("arm", "big_loss")])).set(0.25);
        r.histogram("t_lat", &[1.0]).observe(0.5);
        let text = r.render_prometheus();
        assert!(text.contains("# TYPE t_ticks_total counter"));
        assert!(text.contains("t_ticks_total 7"));
        assert!(text.contains("t_w{arm=\"big_loss\"} 0.25"));
        assert!(text.contains("t_lat_bucket{le=\"1\"} 1"));
        assert!(text.contains("t_lat_bucket{le=\"+Inf\"} 1"));
        assert!(text.contains("t_lat_sum 0.5"));
        assert!(text.contains("t_lat_count 1"));
        // every non-comment line is `name[{labels}] value`
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let (name, value) = line.rsplit_once(' ').unwrap();
            assert!(!name.is_empty());
            assert!(value == "+Inf" || value.parse::<f64>().is_ok(), "bad value in {line}");
        }
    }

    #[test]
    fn snapshot_lists_every_series() {
        let r = Registry::new();
        r.counter("t_a").inc();
        r.gauge("t_b").set(2.0);
        r.histogram("t_c", &[1.0]).observe(3.0);
        let snap = r.snapshot();
        let names: Vec<&str> = snap.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(
            names,
            vec!["t_a", "t_b", "t_c_sum", "t_c_count", "t_c_p50", "t_c_p95", "t_c_p99"]
        );
    }

    #[test]
    fn quantiles_match_known_distributions() {
        let r = Registry::new();
        // uniform 1..=100 over decade buckets: interpolation is exact
        let h = r.histogram("t_q", &[10., 20., 30., 40., 50., 60., 70., 80., 90., 100.]);
        for v in 1..=100 {
            h.observe(v as f64);
        }
        assert!((h.quantile(0.50) - 50.0).abs() < 1e-9, "p50 {}", h.quantile(0.50));
        assert!((h.quantile(0.95) - 95.0).abs() < 1e-9, "p95 {}", h.quantile(0.95));
        assert!((h.quantile(0.99) - 99.0).abs() < 1e-9, "p99 {}", h.quantile(0.99));
        // skewed mass: 90 observations in the first bucket, 10 in the last
        let s = r.histogram("t_skew", &[1.0, 100.0]);
        for _ in 0..90 {
            s.observe(0.5);
        }
        for _ in 0..10 {
            s.observe(60.0);
        }
        assert!(s.quantile(0.50) <= 1.0);
        assert!(s.quantile(0.95) > 1.0 && s.quantile(0.95) <= 100.0);
        // +Inf bucket clamps to the last finite bound
        let c = r.histogram("t_clamp", &[1.0]);
        c.observe(5.0);
        assert_eq!(c.quantile(0.99), 1.0);
        // empty histogram: NaN, never a misleading number
        assert!(r.histogram("t_empty", &[1.0]).quantile(0.5).is_nan());
    }

    #[test]
    fn poisoned_lock_recovers() {
        let r = std::sync::Arc::new(Registry::new());
        r.counter("t_poison").add(3);
        let r2 = std::sync::Arc::clone(&r);
        // poison the metrics mutex by panicking while holding it
        let _ = std::thread::spawn(move || {
            let _guard = r2.metrics.lock().unwrap();
            panic!("poison");
        })
        .join();
        assert!(r.metrics.is_poisoned());
        assert_eq!(r.counter("t_poison").get(), 3);
        assert!(!r.render_prometheus().is_empty());
    }

    #[test]
    fn series_name_builder() {
        assert_eq!(series("x", &[]), "x");
        assert_eq!(series("x", &[("a", "1"), ("b", "2")]), "x{a=\"1\",b=\"2\"}");
    }
}

//! `--status-addr` scrape endpoint: a tiny hand-rolled HTTP/1.0 responder
//! on `std::net::TcpListener` (the crate's existing TCP stack; no HTTP
//! dependency offline).
//!
//! Routes:
//!   * `GET /metrics` — Prometheus text exposition of the whole registry.
//!   * `GET /status`  — JSON: uptime, rolling prequential loss/acc, store
//!     pressure, firing health alerts, and per-node last-heartbeat age
//!     (process clusters). Never-sampled series render as `null`, not 0.
//!   * `GET /profile` — JSON per-kernel streaming p50/p95/p99 digests
//!     from the native backend's continuous profiler (`obs::prof`).
//!
//! The server runs on its own accept thread; requests are served inline
//! (scrapes are rare and tiny), and the training loop never touches it.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::util::json::Json;

use super::registry::registry;

/// Most recently bound status address in this process (tests and log
/// output discover the real port behind `--status-addr 127.0.0.1:0`).
static LAST_BOUND: OnceLock<Mutex<Option<SocketAddr>>> = OnceLock::new();

fn last_bound_slot() -> &'static Mutex<Option<SocketAddr>> {
    LAST_BOUND.get_or_init(|| Mutex::new(None))
}

/// The address the most recent [`StatusServer`] bound, if any. A panic
/// in some other holder must not poison every later lookup, so the
/// guard recovers from poisoning.
pub fn last_bound_addr() -> Option<SocketAddr> {
    *last_bound_slot().lock().unwrap_or_else(|p| p.into_inner())
}

/// A running scrape endpoint; stops (and joins) on [`StatusServer::stop`]
/// or drop.
pub struct StatusServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl StatusServer {
    /// Bind `addr` (e.g. `127.0.0.1:9464`, port 0 for ephemeral) and start
    /// serving.
    pub fn start(addr: &str) -> anyhow::Result<StatusServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("status: cannot bind {addr}: {e}"))?;
        let bound = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        *last_bound_slot().lock().unwrap_or_else(|p| p.into_inner()) = Some(bound);
        log::info!(
            "status endpoint listening on http://{bound} (/metrics, /status, /profile)"
        );
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => serve_one(stream),
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(20));
                    }
                    Err(_) => std::thread::sleep(Duration::from_millis(20)),
                }
            }
        });
        Ok(StatusServer { addr: bound, stop, handle: Some(handle) })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Signal the accept loop and join it.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for StatusServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Cap on the request head we are willing to buffer. Anything larger is
/// rejected with `431` — a scrape request is a few hundred bytes.
const MAX_REQUEST_BYTES: usize = 8192;

enum RequestHead {
    Ok(String),
    TooLarge,
    Empty,
}

/// Read until the blank line ending the request head, tolerating split
/// reads (a client may deliver `GET /sta` and `tus HTTP/1.0\r\n\r\n` in
/// separate segments). A read timeout or EOF serves whatever arrived.
fn read_request_head(stream: &mut TcpStream) -> RequestHead {
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 512];
    loop {
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
                if buf.len() > MAX_REQUEST_BYTES {
                    return RequestHead::TooLarge;
                }
                if buf.windows(4).any(|w| w == b"\r\n\r\n")
                    || buf.windows(2).any(|w| w == b"\n\n")
                {
                    break;
                }
            }
            Err(_) => break, // timeout: a stalled client gets best-effort
        }
    }
    if buf.is_empty() {
        RequestHead::Empty
    } else {
        RequestHead::Ok(String::from_utf8_lossy(&buf).into_owned())
    }
}

fn serve_one(mut stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(Duration::from_millis(500)));
    let request = match read_request_head(&mut stream) {
        RequestHead::Ok(head) => head,
        RequestHead::TooLarge => {
            let _ = write!(
                stream,
                "HTTP/1.0 431 Request Header Fields Too Large\r\nContent-Length: 0\r\nConnection: close\r\n\r\n"
            );
            // drain the rest of the oversized request (bounded by the read
            // timeout) so close() sends a clean FIN instead of an RST that
            // could yank the 431 out of the client's receive buffer
            let mut sink = [0u8; 512];
            while matches!(stream.read(&mut sink), Ok(n) if n > 0) {}
            return;
        }
        RequestHead::Empty => return,
    };
    let path = request
        .lines()
        .next()
        .and_then(|l| l.split_whitespace().nth(1))
        .unwrap_or("/");
    let (status, content_type, body) = match path {
        "/metrics" => (
            "200 OK",
            "text/plain; version=0.0.4",
            registry().render_prometheus(),
        ),
        "/status" | "/" => ("200 OK", "application/json", status_json().to_string()),
        "/profile" => (
            "200 OK",
            "application/json",
            super::prof::profile_json().to_string(),
        ),
        _ => ("404 Not Found", "text/plain", "not found\n".to_string()),
    };
    let _ = write!(
        stream,
        "HTTP/1.0 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    let _ = stream.flush();
}

/// Assemble the `/status` document from the registry snapshot.
fn status_json() -> Json {
    let uptime = registry().uptime_seconds();
    let snap = registry().snapshot();
    let value = |name: &str| snap.iter().find(|(n, _)| n == name).map(|&(_, v)| v);

    // never-sampled series render as null, not 0.0 — "no data yet" must
    // stay distinguishable from a true zero; the pressure division is
    // guarded on a *reported* nonzero capacity
    let live = value("adaselection_store_live");
    let capacity = value("adaselection_store_capacity");
    let pressure = match (live, capacity) {
        (Some(l), Some(c)) if c > 0.0 => Json::from(l / c),
        _ => Json::Null,
    };
    let store = Json::obj(vec![
        ("live", json_num_or_null(live)),
        ("capacity", json_num_or_null(capacity)),
        ("pressure", pressure),
    ]);

    // per-node rows come from the heartbeat gauges the coordinator sets:
    // the gauge holds "uptime seconds at last heartbeat", so the age is a
    // subtraction at scrape time
    let mut nodes: std::collections::BTreeMap<String, Json> = Default::default();
    for (name, v) in &snap {
        if let Some(rest) = name.strip_prefix("adaselection_node_heartbeat_uptime_seconds{node=\"")
        {
            if let Some(node) = rest.strip_suffix("\"}") {
                let ticks = value(&format!(
                    "adaselection_node_ticks_total{{node=\"{node}\"}}"
                ));
                // membership flag from the coordinator's barrier gauges;
                // absent (single-process runs) serializes as null
                let alive = value(&format!("adaselection_node_alive{{node=\"{node}\"}}"))
                    .map(|a| Json::from(a > 0.0))
                    .unwrap_or(Json::Null);
                nodes.insert(
                    node.to_string(),
                    Json::obj(vec![
                        ("heartbeat_age_seconds", Json::from((uptime - v).max(0.0))),
                        ("ticks", json_num_or_null(ticks)),
                        ("alive", alive),
                    ]),
                );
            }
        }
    }

    // per-arm bandit weights, mirrored from the `adaselection_arm_weight`
    // series (`{arm="x"}` for single-process runs, `{node="i",arm="x"}`
    // for clusters — the latter nests node → weight under the arm)
    let mut arms: std::collections::BTreeMap<String, Json> = Default::default();
    let mut arms_by_node: std::collections::BTreeMap<
        String,
        std::collections::BTreeMap<String, Json>,
    > = Default::default();
    for (name, v) in &snap {
        let Some(rest) = name.strip_prefix("adaselection_arm_weight{") else {
            continue;
        };
        let Some(labels) = rest.strip_suffix('}') else { continue };
        let (mut arm, mut node) = (None, None);
        for part in labels.split(',') {
            if let Some((k, val)) = part.split_once('=') {
                let val = val.trim_matches('"').to_string();
                match k {
                    "arm" => arm = Some(val),
                    "node" => node = Some(val),
                    _ => {}
                }
            }
        }
        if let Some(arm) = arm {
            match node {
                Some(n) => {
                    arms_by_node.entry(arm).or_default().insert(n, Json::from(*v));
                }
                None => {
                    arms.insert(arm, Json::from(*v));
                }
            }
        }
    }
    for (arm, per_node) in arms_by_node {
        arms.entry(arm).or_insert(Json::Obj(per_node));
    }

    // fleet membership (cluster runs only): alive node count, parked
    // standbys awaiting an elastic admit, and the measured arrival rate
    let cluster = match value("adaselection_cluster_nodes") {
        Some(n) => Json::obj(vec![
            ("nodes", Json::from(n)),
            (
                "standbys",
                json_num_or_null(value("adaselection_cluster_standbys")),
            ),
            (
                "arrival_rate",
                json_num_or_null(value("adaselection_cluster_arrival_rate")),
            ),
        ]),
        None => Json::Null,
    };

    Json::obj(vec![
        ("uptime_seconds", Json::from(uptime)),
        ("rolling_loss", json_num_or_null(value("adaselection_rolling_loss"))),
        ("rolling_acc", json_num_or_null(value("adaselection_rolling_acc"))),
        ("store", store),
        ("cluster", cluster),
        ("arms", Json::Obj(arms)),
        ("nodes", Json::Obj(nodes)),
        ("alerts", super::health::alerts_json()),
        ("series", Json::from(snap.len())),
        (
            "trace_dropped_lines",
            json_num_or_null(value("adaselection_trace_dropped_lines_total")),
        ),
    ])
}

/// NaN (no eval yet) serializes as `null` — JSON has no NaN literal.
fn json_num_or_null(v: Option<f64>) -> Json {
    match v {
        Some(x) if x.is_finite() => Json::from(x),
        _ => Json::Null,
    }
}

/// Minimal HTTP/1.0 GET used by tests (and handy for debugging).
pub fn http_get(addr: SocketAddr, path: &str) -> anyhow::Result<(u16, String)> {
    let mut stream = TcpStream::connect_timeout(&addr, Duration::from_secs(2))?;
    stream.set_read_timeout(Some(Duration::from_secs(2)))?;
    write!(stream, "GET {path} HTTP/1.0\r\nHost: {addr}\r\n\r\n")?;
    let mut response = String::new();
    stream.read_to_string(&mut response)?;
    let code: u16 = response
        .lines()
        .next()
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|c| c.parse().ok())
        .ok_or_else(|| anyhow::anyhow!("malformed HTTP response"))?;
    let body = response
        .split_once("\r\n\r\n")
        .map(|(_, b)| b.to_string())
        .unwrap_or_default();
    Ok((code, body))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::registry::series;

    #[test]
    fn serves_metrics_status_and_404() {
        registry().counter("adaselection_status_test_total").add(3);
        // register (at zero) so the snapshot always carries the series,
        // whatever other tests ran first in this process
        registry().counter("adaselection_trace_dropped_lines_total");
        registry().gauge("adaselection_store_live").set(10.0);
        registry().gauge("adaselection_store_capacity").set(40.0);
        registry()
            .gauge(&series(
                "adaselection_node_heartbeat_uptime_seconds",
                &[("node", "2")],
            ))
            .set(0.0);
        registry()
            .gauge(&series("adaselection_arm_weight", &[("arm", "status_arm")]))
            .set(0.625);
        registry()
            .gauge(&series("adaselection_node_alive", &[("node", "2")]))
            .set(1.0);
        registry().gauge("adaselection_cluster_nodes").set(3.0);
        registry().gauge("adaselection_cluster_standbys").set(2.0);
        let server = StatusServer::start("127.0.0.1:0").unwrap();
        let addr = server.local_addr();
        assert_eq!(last_bound_addr(), Some(addr));

        let (code, body) = http_get(addr, "/metrics").unwrap();
        assert_eq!(code, 200);
        assert!(body.contains("adaselection_status_test_total 3"));

        let (code, body) = http_get(addr, "/status").unwrap();
        assert_eq!(code, 200);
        let j = Json::parse(&body).unwrap();
        assert!(j.at(&["uptime_seconds"]).unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(j.at(&["store", "pressure"]).unwrap().as_f64().unwrap(), 0.25);
        let nodes = j.at(&["nodes"]).unwrap().as_obj().unwrap();
        assert!(nodes.contains_key("2"));
        assert!(
            nodes["2"].at(&["heartbeat_age_seconds"]).unwrap().as_f64().unwrap() >= 0.0
        );
        // tentpole: the live membership view rides along on /status
        assert_eq!(nodes["2"].at(&["alive"]).unwrap().as_bool().unwrap(), true);
        assert_eq!(j.at(&["cluster", "nodes"]).unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(j.at(&["cluster", "standbys"]).unwrap().as_f64().unwrap(), 2.0);
        // satellite: per-arm weights and trace-drop visibility on /status
        assert_eq!(
            j.at(&["arms", "status_arm"]).unwrap().as_f64().unwrap(),
            0.625
        );
        assert!(j.at(&["trace_dropped_lines"]).unwrap().as_f64().unwrap() >= 0.0);
        // tentpole: the health alerts block rides along on /status
        assert!(j.at(&["alerts", "firing"]).unwrap().as_f64().unwrap() >= 0.0);
        j.at(&["alerts", "active"]).unwrap().as_arr().unwrap();

        // tentpole: /profile serves the per-kernel quantile digests
        crate::obs::prof::record("status_probe", Duration::from_micros(50));
        let (code, body) = http_get(addr, "/profile").unwrap();
        assert_eq!(code, 200);
        let p = Json::parse(&body).unwrap();
        assert!(
            p.at(&["kernels", "status_probe", "count"]).unwrap().as_f64().unwrap() >= 1.0
        );
        assert!(
            p.at(&["kernels", "status_probe", "p50_seconds"]).unwrap().as_f64().unwrap() > 0.0
        );

        let (code, _) = http_get(addr, "/bogus").unwrap();
        assert_eq!(code, 404);

        server.stop();
    }

    #[test]
    fn tolerates_split_request_reads() {
        let server = StatusServer::start("127.0.0.1:0").unwrap();
        let addr = server.local_addr();
        let mut stream =
            TcpStream::connect_timeout(&addr, Duration::from_secs(2)).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
        // deliver the request line in two segments with a pause between
        stream.write_all(b"GET /sta").unwrap();
        stream.flush().unwrap();
        std::thread::sleep(Duration::from_millis(50));
        stream.write_all(b"tus HTTP/1.0\r\n\r\n").unwrap();
        stream.flush().unwrap();
        let mut response = String::new();
        stream.read_to_string(&mut response).unwrap();
        assert!(response.starts_with("HTTP/1.0 200"), "{response}");
        let body = response.split_once("\r\n\r\n").unwrap().1;
        Json::parse(body).expect("split request still yields the JSON body");
        server.stop();
    }

    #[test]
    fn oversized_request_rejected_without_panic() {
        let server = StatusServer::start("127.0.0.1:0").unwrap();
        let addr = server.local_addr();
        let mut stream =
            TcpStream::connect_timeout(&addr, Duration::from_secs(2)).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
        // a request head past MAX_REQUEST_BYTES with no terminating blank
        // line must be refused, not buffered forever or panicked on
        let junk = vec![b'A'; MAX_REQUEST_BYTES + 1024];
        stream.write_all(b"GET /").unwrap();
        stream.write_all(&junk).unwrap();
        stream.flush().unwrap();
        stream.shutdown(std::net::Shutdown::Write).unwrap();
        let mut response = String::new();
        stream.read_to_string(&mut response).unwrap();
        assert!(response.starts_with("HTTP/1.0 431"), "{response}");
        // the server survives and keeps answering normal requests
        let (code, _) = http_get(addr, "/status").unwrap();
        assert_eq!(code, 200);
        server.stop();
    }
}

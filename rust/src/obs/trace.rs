//! Structured trace journal: per-tick JSONL events behind `--trace PATH`.
//!
//! The hot loop hands finished lines to a bounded channel and never
//! blocks on I/O — a dedicated writer thread drains into a `BufWriter`,
//! and when the channel is full the line is *dropped* and counted
//! (`dropped_lines` on [`TraceJournal::finish`]) rather than stalling
//! training. Telemetry must stay off the digest path: the journal only
//! ever receives copies of already-computed state.
//!
//! ## Schema v3
//!
//! One JSON object per line. Common fields: `v` (the schema version the
//! line was written under), `kind`. Validation accepts v1–v3 lines;
//! v1 lines simply predate the `round` field (it defaults to 0) and the
//! `span` kind; v1/v2 lines predate the `alert` kind.
//!
//! * `kind = "tick"` — one per processed tick per node:
//!   `tick`, `node`, `round` (the coordinator's barrier round this tick
//!   ran under; 0 for stream runs and v1 journals), `gamma` (effective γ
//!   this tick), `arrivals`, `trained`, `replayed`, `forward` (candidate
//!   rows forward-scored this tick), `drift` (cumulative detector
//!   fires), `weights` (object arm → weight; present for bandit
//!   policies), `store` (object with `live`, `capacity`, `hits`,
//!   `misses`, `evictions` — cumulative), `phases` (object phase →
//!   seconds spent *this tick*), and optional `rolling` (`loss`, `acc`)
//!   on prequential-eval ticks.
//! * `kind = "gossip"` / `kind = "merge"` — cluster coordinator events:
//!   `tick` (the sync point), `round`, `bytes` (wire bytes this round).
//! * `kind = "span"` (v2 only) — coordinator timing spans: `name`
//!   (`barrier` open→all-ready, `ready_lag` per node, `gossip_relay`,
//!   `merge`), `round`, `tick` (the sync point), optional `node` (set
//!   on per-node spans like `ready_lag`), `start` (seconds since the
//!   coordinator's run clock started), `duration` (seconds).
//! * `kind = "alert"` (v3 only) — health-rule transitions from
//!   `obs::health`: `rule` (e.g. `straggler_ready_lag`), `state`
//!   (`"firing"` or `"resolved"`), `round`, `tick`, optional `node`
//!   (set on per-node rules), `value` (the observed reading that
//!   crossed), `threshold` (the rule's limit at evaluation time).
//!
//! Tick events are tick-contiguous per node: node `n` emits ticks
//! `t, t+1, t+2, ...` without gaps (backfill replays after churn are
//! deliberately not journalled as ticks).

use std::collections::BTreeMap;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::obs::registry::{registry, Counter};
use crate::util::json::Json;
use crate::util::timer::PhaseTimer;

/// Journal schema version emitted in every line.
pub const SCHEMA_VERSION: u64 = 3;
/// Oldest schema version [`validate_line`] still accepts.
pub const MIN_SCHEMA_VERSION: u64 = 1;

/// Lines buffered between the hot loop and the writer thread.
const CHANNEL_CAPACITY: usize = 8192;

/// Owning side of the journal: opens the file, runs the writer thread,
/// and reports drop/flush status on [`TraceJournal::finish`].
pub struct TraceJournal {
    tx: Option<SyncSender<String>>,
    writer: Option<JoinHandle<std::io::Result<()>>>,
    dropped: Arc<AtomicU64>,
    dropped_total: Arc<Counter>,
}

/// Cheap clonable emitter handle (cluster nodes share one journal).
#[derive(Clone)]
pub struct TraceHandle {
    tx: SyncSender<String>,
    dropped: Arc<AtomicU64>,
    dropped_total: Arc<Counter>,
}

impl TraceJournal {
    /// Open `path` for writing and start the writer thread.
    pub fn open(path: &Path) -> anyhow::Result<TraceJournal> {
        let file = std::fs::File::create(path)
            .map_err(|e| anyhow::anyhow!("trace: cannot create {path:?}: {e}"))?;
        let (tx, rx) = sync_channel::<String>(CHANNEL_CAPACITY);
        let writer = std::thread::spawn(move || -> std::io::Result<()> {
            let mut w = BufWriter::new(file);
            while let Ok(line) = rx.recv() {
                w.write_all(line.as_bytes())?;
                w.write_all(b"\n")?;
            }
            w.flush()
        });
        Ok(TraceJournal {
            tx: Some(tx),
            writer: Some(writer),
            dropped: Arc::new(AtomicU64::new(0)),
            dropped_total: registry().counter("adaselection_trace_dropped_lines_total"),
        })
    }

    /// A clonable emitter for this journal.
    pub fn handle(&self) -> TraceHandle {
        TraceHandle {
            tx: self.tx.as_ref().expect("journal already finished").clone(),
            dropped: Arc::clone(&self.dropped),
            dropped_total: Arc::clone(&self.dropped_total),
        }
    }

    /// Close the channel, join the writer (flushing the file), and return
    /// how many lines were dropped under backpressure. Any drops are
    /// WARNed once here and published to the registry
    /// (`adaselection_trace_dropped_lines_total`, also on `/status`) so
    /// overflow is visible without grepping logs.
    pub fn finish(mut self) -> anyhow::Result<u64> {
        self.tx = None; // all emission must go through since-dropped handles
        if let Some(w) = self.writer.take() {
            w.join()
                .map_err(|_| anyhow::anyhow!("trace writer thread panicked"))??;
        }
        let dropped = self.dropped.load(Ordering::Relaxed);
        if dropped > 0 {
            log::warn!("trace: dropped {dropped} journal lines under backpressure");
        }
        Ok(dropped)
    }
}

impl Drop for TraceJournal {
    fn drop(&mut self) {
        self.tx = None;
        if let Some(w) = self.writer.take() {
            let _ = w.join();
        }
    }
}

impl TraceHandle {
    /// Enqueue one already-serialized line; drops (and counts, both in
    /// the journal and the live registry counter) when the writer is
    /// behind instead of blocking the hot loop.
    pub fn emit(&self, line: String) {
        match self.tx.try_send(line) {
            Ok(()) => {}
            Err(TrySendError::Full(_)) | Err(TrySendError::Disconnected(_)) => {
                self.dropped.fetch_add(1, Ordering::Relaxed);
                self.dropped_total.inc();
            }
        }
    }

    /// Emit a coordinator-side gossip/merge event.
    pub fn emit_wire_event(&self, kind: &str, round: u64, tick: u64, bytes: u64) {
        self.emit(wire_event_line(kind, round, tick, bytes));
    }

    /// Emit a coordinator-side timing span (v2): `name` scopes what was
    /// measured (`barrier`, `ready_lag`, `gossip_relay`, `merge`),
    /// `start`/`duration` are seconds on the coordinator's run clock
    /// ([`crate::util::timer::Stopwatch`]), `node` is set on per-node
    /// spans like `ready_lag`.
    pub fn emit_span(
        &self,
        name: &str,
        round: u64,
        tick: u64,
        node: Option<usize>,
        start: f64,
        duration: f64,
    ) {
        self.emit(span_line(name, round, tick, node, start, duration));
    }

    /// Emit a health-rule transition (v3): `rule` names the built-in
    /// rule, `state` is `"firing"` or `"resolved"`, `value` is the
    /// reading that crossed and `threshold` the rule's limit.
    #[allow(clippy::too_many_arguments)]
    pub fn emit_alert(
        &self,
        rule: &str,
        state: &str,
        round: u64,
        tick: u64,
        node: Option<usize>,
        value: f64,
        threshold: f64,
    ) {
        self.emit(alert_line(rule, state, round, tick, node, value, threshold));
    }
}

/// Serialize one gossip/merge wire event line (shared by the live
/// journal and the flight recorder, which must agree byte-for-byte).
pub fn wire_event_line(kind: &str, round: u64, tick: u64, bytes: u64) -> String {
    Json::obj(vec![
        ("v", Json::from(SCHEMA_VERSION as usize)),
        ("kind", Json::from(kind)),
        ("round", Json::from(round as usize)),
        ("tick", Json::from(tick as usize)),
        ("bytes", Json::from(bytes as usize)),
    ])
    .to_string()
}

/// Serialize one coordinator timing-span line.
pub fn span_line(
    name: &str,
    round: u64,
    tick: u64,
    node: Option<usize>,
    start: f64,
    duration: f64,
) -> String {
    let mut pairs = vec![
        ("v", Json::from(SCHEMA_VERSION as usize)),
        ("kind", Json::from("span")),
        ("name", Json::from(name)),
        ("round", Json::from(round as usize)),
        ("tick", Json::from(tick as usize)),
    ];
    if let Some(n) = node {
        pairs.push(("node", Json::from(n)));
    }
    pairs.push(("start", Json::from(start)));
    pairs.push(("duration", Json::from(duration)));
    Json::obj(pairs).to_string()
}

/// Serialize one schema-v3 `kind:"alert"` line (shared by the live
/// journal and the flight recorder, which must agree byte-for-byte).
pub fn alert_line(
    rule: &str,
    state: &str,
    round: u64,
    tick: u64,
    node: Option<usize>,
    value: f64,
    threshold: f64,
) -> String {
    fn num(v: f64) -> Json {
        if v.is_finite() { Json::from(v) } else { Json::Null }
    }
    let mut pairs = vec![
        ("v", Json::from(SCHEMA_VERSION as usize)),
        ("kind", Json::from("alert")),
        ("rule", Json::from(rule)),
        ("state", Json::from(state)),
        ("round", Json::from(round as usize)),
        ("tick", Json::from(tick as usize)),
    ];
    if let Some(n) = node {
        pairs.push(("node", Json::from(n)));
    }
    pairs.push(("value", num(value)));
    pairs.push(("threshold", num(threshold)));
    Json::obj(pairs).to_string()
}

/// Everything a `kind:"tick"` line carries, assembled by the caller
/// *after* the tick's training work is complete.
pub struct TickEvent<'a> {
    pub tick: u64,
    pub node: usize,
    /// Barrier round this tick ran under (0 for stream runs).
    pub round: u64,
    pub gamma: f32,
    pub arrivals: usize,
    pub trained: usize,
    pub replayed: usize,
    /// Candidate rows forward-scored this tick.
    pub forward: u64,
    /// Cumulative drift-detector fires.
    pub drift: u64,
    /// `(arm id, weight)` pairs; empty for single-method policies.
    pub weights: &'a [(String, f32)],
    pub store_live: usize,
    pub store_capacity: usize,
    pub store_hits: u64,
    pub store_misses: u64,
    pub store_evictions: u64,
    /// Per-phase seconds spent this tick.
    pub phases: &'a [(String, f64)],
    /// `(rolling_loss, rolling_acc)` on eval ticks.
    pub rolling: Option<(f32, f32)>,
}

impl TickEvent<'_> {
    /// Serialize as one current-schema JSONL line.
    pub fn to_line(&self) -> String {
        // NaN/Inf have no JSON spelling (rolling acc is NaN on regression
        // streams); journal them as null so every line stays parseable
        fn num(v: f64) -> Json {
            if v.is_finite() { Json::from(v) } else { Json::Null }
        }
        let weights = Json::Obj(
            self.weights
                .iter()
                .map(|(id, w)| (id.clone(), num(*w as f64)))
                .collect(),
        );
        let phases = Json::Obj(
            self.phases
                .iter()
                .map(|(p, s)| (p.clone(), Json::from(*s)))
                .collect(),
        );
        let store = Json::obj(vec![
            ("live", Json::from(self.store_live)),
            ("capacity", Json::from(self.store_capacity)),
            ("hits", Json::from(self.store_hits as usize)),
            ("misses", Json::from(self.store_misses as usize)),
            ("evictions", Json::from(self.store_evictions as usize)),
        ]);
        let mut pairs = vec![
            ("v", Json::from(SCHEMA_VERSION as usize)),
            ("kind", Json::from("tick")),
            ("tick", Json::from(self.tick as usize)),
            ("node", Json::from(self.node)),
            ("round", Json::from(self.round as usize)),
            ("gamma", num(self.gamma as f64)),
            ("arrivals", Json::from(self.arrivals)),
            ("trained", Json::from(self.trained)),
            ("replayed", Json::from(self.replayed)),
            ("forward", Json::from(self.forward as usize)),
            ("drift", Json::from(self.drift as usize)),
            ("weights", weights),
            ("store", store),
            ("phases", phases),
        ];
        if let Some((loss, acc)) = self.rolling {
            pairs.push((
                "rolling",
                Json::obj(vec![("loss", num(loss as f64)), ("acc", num(acc as f64))]),
            ));
        }
        Json::obj(pairs).to_string()
    }
}

/// Computes per-tick phase deltas from the cumulative [`PhaseTimer`].
#[derive(Default)]
pub struct PhaseDelta {
    prev: BTreeMap<String, Duration>,
}

impl PhaseDelta {
    /// `(phase, seconds since the previous call)` for every phase that
    /// advanced, in BTreeMap (alphabetical) order.
    pub fn delta(&mut self, timer: &PhaseTimer) -> Vec<(String, f64)> {
        let mut out = Vec::new();
        for (phase, total) in timer.phases() {
            let prev = self.prev.get(phase).copied().unwrap_or_default();
            if total > prev {
                out.push((phase.to_string(), (total - prev).as_secs_f64()));
            }
            self.prev.insert(phase.to_string(), total);
        }
        out
    }
}

/// A parsed-and-validated journal line (tests + tooling).
#[derive(Debug)]
pub struct ParsedEvent {
    pub kind: String,
    pub tick: u64,
    /// Barrier round; 0 on v1 lines (which predate the field) and on
    /// stream-run tick events.
    pub round: u64,
    /// Present on `tick` events and per-node spans.
    pub node: Option<usize>,
    /// Present on `span` events.
    pub name: Option<String>,
    /// Present on `alert` events: `(rule, state)`.
    pub alert: Option<(String, String)>,
}

/// Validate one journal line against schema v1, v2, *or* v3 (the
/// compatibility rules: v1 lines carry no `round` — it defaults to 0 —
/// and cannot carry `span` events; `alert` events require v3; anything
/// past [`SCHEMA_VERSION`] is rejected).
pub fn validate_line(line: &str) -> anyhow::Result<ParsedEvent> {
    let j = Json::parse(line).map_err(|e| anyhow::anyhow!("trace line is not JSON: {e:?}"))?;
    let v = j.at(&["v"])?.as_usize()? as u64;
    anyhow::ensure!(
        (MIN_SCHEMA_VERSION..=SCHEMA_VERSION).contains(&v),
        "schema version {v} outside v{MIN_SCHEMA_VERSION}..v{SCHEMA_VERSION}"
    );
    let kind = j.at(&["kind"])?.as_str()?.to_string();
    let tick = j.at(&["tick"])?.as_usize()? as u64;
    let round = if v >= 2 { j.at(&["round"])?.as_usize()? as u64 } else { 0 };
    let mut alert = None;
    let (node, name) = match kind.as_str() {
        "tick" => {
            for field in
                ["gamma", "arrivals", "trained", "replayed", "forward", "drift"]
            {
                j.at(&[field])?.as_f64()?;
            }
            j.at(&["weights"])?.as_obj()?;
            let store = j.at(&["store"])?;
            for field in ["live", "capacity", "hits", "misses", "evictions"] {
                store.at(&[field])?.as_f64()?;
            }
            j.at(&["phases"])?.as_obj()?;
            (Some(j.at(&["node"])?.as_usize()?), None)
        }
        "gossip" | "merge" => {
            j.at(&["bytes"])?.as_f64()?;
            (None, None)
        }
        "span" => {
            anyhow::ensure!(v >= 2, "span events require schema v2");
            let name = j.at(&["name"])?.as_str()?.to_string();
            j.at(&["start"])?.as_f64()?;
            j.at(&["duration"])?.as_f64()?;
            let node = match j.get("node") {
                Some(n) => Some(n.as_usize()?),
                None => None,
            };
            (node, Some(name))
        }
        "alert" => {
            anyhow::ensure!(v >= 3, "alert events require schema v3");
            let rule = j.at(&["rule"])?.as_str()?.to_string();
            let state = j.at(&["state"])?.as_str()?.to_string();
            anyhow::ensure!(
                state == "firing" || state == "resolved",
                "alert state '{state}' is neither 'firing' nor 'resolved'"
            );
            j.at(&["value"])?; // present; may be null for non-finite readings
            j.at(&["threshold"])?;
            let node = match j.get("node") {
                Some(n) => Some(n.as_usize()?),
                None => None,
            };
            alert = Some((rule, state));
            (node, None)
        }
        other => anyhow::bail!("unknown trace kind '{other}'"),
    };
    Ok(ParsedEvent { kind, tick, round, node, name, alert })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_event() -> String {
        TickEvent {
            tick: 3,
            node: 1,
            round: 2,
            gamma: 0.5,
            arrivals: 128,
            trained: 64,
            replayed: 2,
            forward: 64,
            drift: 1,
            weights: &[("big_loss".to_string(), 0.7), ("uniform".to_string(), 0.3)],
            store_live: 100,
            store_capacity: 4096,
            store_hits: 10,
            store_misses: 90,
            store_evictions: 0,
            phases: &[("forward".to_string(), 0.001), ("update".to_string(), 0.002)],
            rolling: Some((1.25, 0.5)),
        }
        .to_line()
    }

    #[test]
    fn tick_event_round_trips_schema_v2() {
        let line = sample_event();
        let ev = validate_line(&line).unwrap();
        assert_eq!(ev.kind, "tick");
        assert_eq!(ev.tick, 3);
        assert_eq!(ev.round, 2);
        assert_eq!(ev.node, Some(1));
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.at(&["weights", "big_loss"]).unwrap().as_f64().unwrap() as f32, 0.7);
        assert_eq!(j.at(&["rolling", "acc"]).unwrap().as_f64().unwrap() as f32, 0.5);
    }

    #[test]
    fn wire_events_validate() {
        // a v1 coordinator event (no round) still validates, round = 0
        let j = Json::obj(vec![
            ("v", Json::from(1usize)),
            ("kind", Json::from("gossip")),
            ("tick", Json::from(16usize)),
            ("bytes", Json::from(2048usize)),
        ]);
        let ev = validate_line(&j.to_string()).unwrap();
        assert_eq!(ev.kind, "gossip");
        assert_eq!(ev.round, 0);
        assert_eq!(ev.node, None);
        // the v2 emitter carries the round
        let journal_line = {
            let dir = std::env::temp_dir().join(format!("ada_wire_ev_{}", std::process::id()));
            std::fs::create_dir_all(&dir).unwrap();
            let path = dir.join("w.jsonl");
            let journal = TraceJournal::open(&path).unwrap();
            journal.handle().emit_wire_event("merge", 5, 80, 4096);
            journal.finish().unwrap();
            let text = std::fs::read_to_string(&path).unwrap();
            std::fs::remove_file(&path).ok();
            text.lines().next().unwrap().to_string()
        };
        let ev = validate_line(&journal_line).unwrap();
        assert_eq!(ev.kind, "merge");
        assert_eq!(ev.round, 5);
        assert_eq!(ev.tick, 80);
    }

    #[test]
    fn span_events_validate() {
        let dir = std::env::temp_dir().join(format!("ada_span_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("s.jsonl");
        let journal = TraceJournal::open(&path).unwrap();
        let h = journal.handle();
        h.emit_span("barrier", 3, 40, None, 1.25, 0.5);
        h.emit_span("ready_lag", 3, 40, Some(2), 1.25, 0.125);
        drop(h);
        journal.finish().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let barrier = validate_line(lines[0]).unwrap();
        assert_eq!(barrier.kind, "span");
        assert_eq!(barrier.name.as_deref(), Some("barrier"));
        assert_eq!(barrier.round, 3);
        assert_eq!(barrier.node, None);
        let lag = validate_line(lines[1]).unwrap();
        assert_eq!(lag.name.as_deref(), Some("ready_lag"));
        assert_eq!(lag.node, Some(2));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn alert_events_validate() {
        let firing = alert_line("straggler_ready_lag", "firing", 4, 64, Some(2), 1.5, 0.4);
        let ev = validate_line(&firing).unwrap();
        assert_eq!(ev.kind, "alert");
        assert_eq!(ev.round, 4);
        assert_eq!(ev.tick, 64);
        assert_eq!(ev.node, Some(2));
        assert_eq!(
            ev.alert,
            Some(("straggler_ready_lag".to_string(), "firing".to_string()))
        );
        // fleet-wide alerts carry no node; non-finite readings become null
        let resolved = alert_line("rolling_loss_nonfinite", "resolved", 5, 80, None, f64::NAN, 0.0);
        let ev = validate_line(&resolved).unwrap();
        assert_eq!(ev.node, None);
        assert_eq!(ev.alert.unwrap().1, "resolved");
    }

    #[test]
    fn bad_lines_are_rejected() {
        assert!(validate_line("not json").is_err());
        // v2 tick line missing every required field
        assert!(validate_line("{\"v\":2,\"kind\":\"tick\",\"tick\":0}").is_err());
        assert!(validate_line("{\"v\":1,\"kind\":\"bogus\",\"tick\":0}").is_err());
        // future schema versions are rejected outright
        assert!(validate_line(
            "{\"v\":4,\"kind\":\"gossip\",\"round\":0,\"tick\":0,\"bytes\":0}"
        )
        .is_err());
        // alerts did not exist before v3
        assert!(validate_line(
            "{\"v\":2,\"kind\":\"alert\",\"rule\":\"straggler_ready_lag\",\
             \"state\":\"firing\",\"round\":1,\"tick\":8,\"value\":1.0,\"threshold\":0.5}"
        )
        .is_err());
        // an alert state outside firing/resolved is rejected
        assert!(validate_line(
            "{\"v\":3,\"kind\":\"alert\",\"rule\":\"x\",\"state\":\"flapping\",\
             \"round\":1,\"tick\":8,\"value\":1.0,\"threshold\":0.5}"
        )
        .is_err());
        // spans did not exist in v1
        assert!(validate_line(
            "{\"v\":1,\"kind\":\"span\",\"name\":\"barrier\",\"tick\":0,\
             \"start\":0.0,\"duration\":0.1}"
        )
        .is_err());
        // a v2 wire event without its round is rejected
        assert!(validate_line("{\"v\":2,\"kind\":\"gossip\",\"tick\":16,\"bytes\":10}").is_err());
        // a tick event missing its store block is rejected
        assert!(validate_line(
            "{\"v\":1,\"kind\":\"tick\",\"tick\":0,\"node\":0,\"gamma\":0.5,\
             \"arrivals\":1,\"trained\":1,\"replayed\":0,\"forward\":0,\
             \"drift\":0,\"weights\":{},\"phases\":{}}"
        )
        .is_err());
    }

    #[test]
    fn journal_writes_flush_and_count_nothing_dropped() {
        let dir = std::env::temp_dir().join(format!("ada_trace_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("j.jsonl");
        let journal = TraceJournal::open(&path).unwrap();
        let h = journal.handle();
        for _ in 0..100 {
            h.emit(sample_event());
        }
        drop(h);
        assert_eq!(journal.finish().unwrap(), 0);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 100);
        for line in text.lines() {
            validate_line(line).unwrap();
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn phase_delta_tracks_increments() {
        let mut timer = PhaseTimer::default();
        timer.add("forward", Duration::from_millis(10));
        let mut d = PhaseDelta::default();
        let first = d.delta(&timer);
        assert_eq!(first.len(), 1);
        assert!((first[0].1 - 0.010).abs() < 1e-9);
        // no advance → no rows
        assert!(d.delta(&timer).is_empty());
        timer.add("forward", Duration::from_millis(5));
        timer.add("update", Duration::from_millis(2));
        let next = d.delta(&timer);
        assert_eq!(next.len(), 2);
        assert!((next[0].1 - 0.005).abs() < 1e-9); // forward delta only
        assert!((next[1].1 - 0.002).abs() < 1e-9);
    }
}

//! Crash flight recorder: an always-on bounded in-memory ring of the
//! most recent journal lines (tick/span/wire/alert events), independent
//! of `--trace`.
//!
//! Every event serializer already produces schema-valid JSONL; the ring
//! keeps the last [`FLIGHT_CAPACITY`] of them so a post-mortem has a
//! validated journal tail even when tracing was off. The ring is dumped
//! to `<path>.flight.jsonl` on:
//!
//!   * a panic anywhere in the process (chained panic hook),
//!   * `SIGTERM` (unix; the handler re-raises the default exit), and
//!   * the process coordinator converting a dead worker into kill-churn
//!     (the worker itself got `SIGKILL` and cannot dump — the
//!     coordinator's ring carries the fleet's last rounds instead).
//!
//! Recording is a short mutex-guarded push of an already-built string —
//! strictly off the digest path, like the rest of `obs`.

use std::collections::VecDeque;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};

/// Lines the ring retains — sized for several rounds of a wide fleet
/// (a 4-node round is ~4 tick lines per tick plus a handful of spans).
pub const FLIGHT_CAPACITY: usize = 4096;

/// A bounded ring of serialized journal lines.
pub struct FlightRing {
    lines: Mutex<VecDeque<String>>,
    capacity: usize,
}

impl FlightRing {
    pub fn new(capacity: usize) -> FlightRing {
        FlightRing { lines: Mutex::new(VecDeque::with_capacity(capacity)), capacity }
    }

    fn lock(&self) -> MutexGuard<'_, VecDeque<String>> {
        // a panicked recorder must not take the dump path down with it
        self.lines.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Append one already-serialized journal line, evicting the oldest
    /// once the ring is full.
    pub fn record(&self, line: String) {
        let mut q = self.lock();
        if q.len() == self.capacity {
            q.pop_front();
        }
        q.push_back(line);
    }

    /// Current ring contents, oldest first.
    pub fn snapshot(&self) -> Vec<String> {
        self.lock().iter().cloned().collect()
    }

    /// Best-effort snapshot that never blocks — safe to call from a
    /// signal handler where the recording thread may hold the lock.
    fn snapshot_try(&self) -> Option<Vec<String>> {
        match self.lines.try_lock() {
            Ok(q) => Some(q.iter().cloned().collect()),
            Err(std::sync::TryLockError::Poisoned(p)) => {
                Some(p.into_inner().iter().cloned().collect())
            }
            Err(std::sync::TryLockError::WouldBlock) => None,
        }
    }

    /// Write the ring to `path` as JSONL, oldest line first. Returns the
    /// number of lines written.
    pub fn dump_to(&self, path: &Path) -> std::io::Result<usize> {
        let lines = self.snapshot_try().unwrap_or_default();
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        for line in &lines {
            w.write_all(line.as_bytes())?;
            w.write_all(b"\n")?;
        }
        w.flush()?;
        Ok(lines.len())
    }
}

static FLIGHT: OnceLock<FlightRing> = OnceLock::new();
static DUMP_PATH: OnceLock<Mutex<Option<PathBuf>>> = OnceLock::new();
static HOOKS_INSTALLED: AtomicBool = AtomicBool::new(false);

/// The process-wide flight ring.
pub fn flight() -> &'static FlightRing {
    FLIGHT.get_or_init(|| FlightRing::new(FLIGHT_CAPACITY))
}

/// Record one line into the process-wide ring.
pub fn record(line: String) {
    flight().record(line);
}

fn dump_path_slot() -> &'static Mutex<Option<PathBuf>> {
    DUMP_PATH.get_or_init(|| Mutex::new(None))
}

/// Derive the dump path for a run: `<trace>.flight.jsonl` next to the
/// journal when tracing, else `adaselection.flight.jsonl` in the cwd.
pub fn default_dump_path(trace: Option<&Path>) -> PathBuf {
    match trace {
        Some(p) => {
            let mut s = p.as_os_str().to_os_string();
            s.push(".flight.jsonl");
            PathBuf::from(s)
        }
        None => PathBuf::from("adaselection.flight.jsonl"),
    }
}

/// Set where crash dumps land for this process.
pub fn set_dump_path(path: PathBuf) {
    *dump_path_slot().lock().unwrap_or_else(|p| p.into_inner()) = Some(path);
}

/// The configured dump path, if any.
pub fn dump_path() -> Option<PathBuf> {
    dump_path_slot().lock().unwrap_or_else(|p| p.into_inner()).clone()
}

/// Dump the ring to the configured path now (e.g. on coordinator
/// crash-conversion). Returns the path written, or `None` when no path
/// is configured or the write failed — a failed post-mortem dump must
/// never escalate the original failure.
pub fn dump_now(reason: &str) -> Option<PathBuf> {
    let path = dump_path()?;
    match flight().dump_to(&path) {
        Ok(n) => {
            log::warn!("flight recorder: dumped {n} lines to {path:?} ({reason})");
            Some(path)
        }
        Err(e) => {
            log::warn!("flight recorder: dump to {path:?} failed: {e}");
            None
        }
    }
}

#[cfg(unix)]
mod sig {
    /// `signal(2)` from the already-linked C runtime — the offline build
    /// carries no libc crate. Registering a plain fn pointer is the
    /// oldest stable slice of the API and all we need for a best-effort
    /// dump-and-exit on SIGTERM.
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }

    const SIGTERM: i32 = 15;

    extern "C" fn on_sigterm(_sig: i32) {
        super::dump_now("sigterm");
        // 128 + SIGTERM: the conventional exit code for a terminated run
        std::process::exit(143);
    }

    pub fn install() {
        unsafe {
            signal(SIGTERM, on_sigterm as usize);
        }
    }
}

/// Install the crash hooks once per process: a chained panic hook and
/// (unix) a SIGTERM handler, both dumping the ring to the configured
/// path before the process dies.
pub fn install_crash_hooks() {
    if HOOKS_INSTALLED.swap(true, Ordering::SeqCst) {
        return;
    }
    let prev = std::panic::take_hook();
    std::panic::set_hook(Box::new(move |info| {
        dump_now("panic");
        prev(info);
    }));
    #[cfg(unix)]
    sig::install();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_bounds_and_dumps() {
        let ring = FlightRing::new(4);
        for i in 0..10 {
            ring.record(format!("{{\"line\":{i}}}"));
        }
        let snap = ring.snapshot();
        assert_eq!(snap.len(), 4);
        assert_eq!(snap[0], "{\"line\":6}");
        assert_eq!(snap[3], "{\"line\":9}");
        let dir = std::env::temp_dir().join(format!("ada_flight_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ring.flight.jsonl");
        assert_eq!(ring.dump_to(&path).unwrap(), 4);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 4);
        assert_eq!(text.lines().last().unwrap(), "{\"line\":9}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn dump_path_derivation() {
        assert_eq!(
            default_dump_path(Some(Path::new("/tmp/out/trace.jsonl"))),
            PathBuf::from("/tmp/out/trace.jsonl.flight.jsonl")
        );
        assert_eq!(default_dump_path(None), PathBuf::from("adaselection.flight.jsonl"));
    }

    #[test]
    fn recorded_journal_lines_validate_from_a_dump() {
        use crate::obs::trace;
        let ring = FlightRing::new(16);
        ring.record(trace::alert_line("heartbeat_stale", "firing", 2, 32, Some(1), 9.0, 5.0));
        for line in ring.snapshot() {
            trace::validate_line(&line).unwrap();
        }
    }
}
